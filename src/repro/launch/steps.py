"""Jittable train / prefill / decode steps with full sharding annotations.

These are the functions the launcher jits for real runs and the dry-run
lowers with ShapeDtypeStructs; one definition serves both.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models import transformer as tfm
from repro.models.config import ModelConfig
from repro.optim import OptConfig, TrainState, apply_updates, zero_spec_tree
from repro.parallel import constrain, filter_spec

PyTree = Any


def batch_spec_tree(batch_tree):
    """Shard every batch leaf's leading dim over the DP axes."""
    def spec(leaf):
        nd = len(leaf.shape)
        return P(("pod", "data"), *(None,) * (nd - 1))

    return jax.tree.map(spec, batch_tree)


def cache_spec_tree(cfg: ModelConfig, cache_tree):
    """KV caches: batch over DP axes; KV-head axis over model when the head
    count divides 16, otherwise the head_dim axis (GQA models with few KV
    heads). SSM states: batch over DP, heads/channels over model."""
    kv_on_heads = cfg.n_kv_heads % 16 == 0

    def spec(path, leaf):
        name = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
        nd = len(leaf.shape)
        if name in ("k", "v", "ek", "ev"):
            # [L, B, S, KV, hd]
            if kv_on_heads:
                return P(None, ("pod", "data"), None, "model", None)
            return P(None, ("pod", "data"), None, None, "model")
        if name == "conv":
            # [L, B, K-1, ch]
            return P(None, ("pod", "data"), None, "model")
        if name == "ssm":
            # [L, B, H, N, P]
            return P(None, ("pod", "data"), "model", None, None)
        if name == "len":
            return P(("pod", "data"))
        return P(*(None,) * nd)

    return jax.tree_util.tree_map_with_path(spec, cache_tree)


def make_train_step(cfg: ModelConfig, opt: OptConfig,
                    pod_wire: str | None = None,
                    microbatch: int | None = None):
    """Returns (train_step, param_specs, zero_specs). State: fp32 master/m/v,
    sharded model×data; compute params materialized in cfg.dtype per step.

    ``pod_wire`` ('u16'|'u8', §Perf C): run the step per pod (shard_map
    manual over the 'pod' axis only) and reduce gradients across pods with
    the integer-wire compressed reduction — the paper's bit-packing idea
    applied to the inter-pod DCI link. Requires the multi-pod mesh.
    """
    shapes, specs = tfm.abstract_params(cfg)
    zspecs = zero_spec_tree(specs, shapes)
    cdtype = jnp.dtype(cfg.dtype)

    def to_compute(master):
        # stacked layer params stay in master dtype/sharding; the layer scan
        # casts one layer at a time (§Perf B4a), so the full compute-param
        # stack never materializes
        out = {}
        for key, sub in master.items():
            if key in ("blocks", "enc_blocks"):
                out[key] = sub
                continue
            leaves, treedef = jax.tree.flatten(sub)
            sp_leaves = jax.tree.flatten(
                specs[key], is_leaf=lambda s: isinstance(s, P))[0]
            out[key] = jax.tree.unflatten(
                treedef, [constrain(x.astype(cdtype), sp)
                          for x, sp in zip(leaves, sp_leaves)])
        return out

    def loss_fn(master, batch):
        params = to_compute(master)   # all-gather over 'data' (ZeRO)
        return tfm.forward_train(cfg, params, batch)

    def grads_of(master, batch):
        if microbatch is None:
            return jax.value_and_grad(loss_fn)(master, batch)
        # gradient accumulation (activation residency ∝ microbatch size);
        # the stacked layout is pinned so the loop dim is replicated and
        # each microbatch keeps the DP sharding (otherwise the reshape of
        # the DP-sharded batch dim confuses the SPMD partitioner)
        gb = jax.tree.leaves(batch)[0].shape[0]
        n_micro = gb // microbatch
        stacked = jax.tree.map(
            lambda x: constrain(
                x.reshape((n_micro, microbatch) + x.shape[1:]),
                P(None, ("pod", "data"), *([None] * (x.ndim - 1)))),
            batch)

        def acc(carry, mb):
            ls, gs = carry
            l, g = jax.value_and_grad(loss_fn)(master, mb)
            return (ls + l, jax.tree.map(jnp.add, gs, g)), None

        zero_g = jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32),
                              master)
        (ls, gs), _ = jax.lax.scan(acc, (jnp.zeros((), jnp.float32), zero_g),
                                   stacked)
        return ls / n_micro, jax.tree.map(lambda g: g / n_micro, gs)

    def train_step(state: TrainState, batch) -> tuple[TrainState, dict]:
        loss, grads = grads_of(state.master, batch)
        new_state = apply_updates(state, grads, opt, zero_specs=zspecs)
        return new_state, {"loss": loss}

    if pod_wire is None:
        return train_step, specs, zspecs

    from repro.optim.compression import compressed_wire_reduce
    from repro.parallel import current_mesh, shard_map_compat

    def constrain_tree(tree, spec_tree):
        leaves, treedef = jax.tree.flatten(tree)
        sp = jax.tree.flatten(spec_tree,
                              is_leaf=lambda s: isinstance(s, P))[0]
        return jax.tree.unflatten(
            treedef, [constrain(x, s) for x, s in zip(leaves, sp)])

    def pod_body(state: TrainState, batch):
        # the shard_map boundary (in_specs only name the manual 'pod' axis)
        # drops the auto-axes layout — re-pin the ZeRO sharding or GSPMD
        # re-gathers the fp32 master per layer (measured: 90 GB/device)
        state = TrainState(state.step,
                           constrain_tree(state.master, zspecs),
                           constrain_tree(state.m, zspecs),
                           constrain_tree(state.v, zspecs))
        batch = jax.tree.map(
            lambda b: constrain(b, P(("data",), *([None] * (b.ndim - 1)))),
            batch)
        loss, grads = jax.value_and_grad(loss_fn)(state.master, batch)
        grads = constrain_tree(grads, zspecs)
        grads = jax.tree.map(
            lambda g: compressed_wire_reduce(g, "pod", 2, wire=pod_wire),
            grads)
        grads = constrain_tree(grads, zspecs)
        loss = jax.lax.pmean(loss, "pod")
        new_state = apply_updates(state, grads, opt, zero_specs=zspecs)
        new_state = TrainState(new_state.step,
                               constrain_tree(new_state.master, zspecs),
                               constrain_tree(new_state.m, zspecs),
                               constrain_tree(new_state.v, zspecs))
        return new_state, {"loss": loss}

    def train_step_pod(state: TrainState, batch):
        mesh = current_mesh()
        rep = jax.tree.map(lambda _: P(), state)
        bspec = jax.tree.map(lambda _: P("pod"), batch)
        fn = shard_map_compat(pod_body, mesh,
                              in_specs=(rep, bspec),
                              out_specs=(rep, {"loss": P()}),
                              axis_names={"pod"})
        return fn(state, batch)

    return train_step_pod, specs, zspecs


def make_prefill_step(cfg: ModelConfig, max_len: int):
    specs = tfm.param_specs(cfg)

    def prefill_step(params, batch):
        return tfm.forward_prefill(cfg, params, batch, max_len)

    return prefill_step, specs


def make_decode_step(cfg: ModelConfig):
    """serve_step: one new token against an existing KV cache."""
    specs = tfm.param_specs(cfg)

    def decode_step(params, tokens, cache):
        logits, new_cache = tfm.forward_decode(cfg, params, tokens, cache)
        next_tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return next_tok[:, None], new_cache

    return decode_step, specs
