import os
if "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Hotspot analyzer for one dry-run cell: ranks collectives and top
byte/flop instructions by (cost × loop trips), with jax op provenance from
HLO metadata. The instrument behind every §Perf hypothesis.

    PYTHONPATH=src python -m repro.launch.analyze --arch dbrx-132b \
        --shape train_4k [--multi-pod] [--top 25]
"""
import argparse            # noqa: E402
import re                  # noqa: E402
from collections import defaultdict  # noqa: E402

from repro.launch import hlo_cost as hc  # noqa: E402

_OPNAME = re.compile(r'op_name="([^"]*)"')


def _collect_instrs(hlo: str):
    """(comp_name, opcode, result_bytes, wire_bytes, flops, op_name) rows."""
    p = hc.parse(hlo)
    rows = []
    cur = None
    for raw in hlo.splitlines():
        line = raw.strip()
        if line.endswith("{") and "->" in line:
            m = hc._COMP_HDR.match(line)
            if m:
                cur = m.group(2)
            continue
        if line.startswith("}"):
            continue
        m = hc._INSTR.match(line)
        if not m or cur is None:
            continue
        name, rhs = m.group(1), m.group(2)
        rhs_core = re.split(r",\s*(?:metadata=|backend_config=)", rhs)[0]
        opcode = hc._opcode_of(rhs_core)
        if opcode is None:
            continue
        head = rhs_core.split(opcode + "(", 1)[0]
        res = hc._nbytes(hc._shapes_in(head))
        if opcode in hc._SKIP_BYTES:      # match aggregate()'s byte rules
            res = 0
        wire = hc._wire_bytes(opcode, res, rhs) \
            if opcode in hc.COLLECTIVES else 0.0
        flops = 0.0
        if opcode == "dot":
            ops_ = hc._operand_names(rhs_core, opcode)
            cd = re.search(r"lhs_contracting_dims={([0-9,]*)}", rhs)
            lhs = p.sym_first(ops_[0]) if ops_ else None
            k = 1
            if cd is not None and lhs is not None and cd.group(1):
                for idx in cd.group(1).split(","):
                    k *= lhs[1][int(idx)]
            nres = 1
            shapes = hc._shapes_in(head)
            if shapes:
                for s in shapes[0][1]:
                    nres *= s
            flops = 2.0 * nres * k
        om = _OPNAME.search(rhs)
        rows.append((cur, opcode, res, wire, flops,
                     om.group(1) if om else ""))
    return p, rows


def _trip_multipliers(p: hc._Parsed, entry: str) -> dict:
    """comp name -> product of enclosing while trip counts."""
    mult = defaultdict(float)

    def walk(name, factor, depth=0):
        if depth > 64:
            return
        mult[name] = mult[name] + factor if name in mult else factor
        c = p.comps.get(name)
        if c is None:
            return
        for callee in c.calls + c.fusion_calls:
            walk(callee, factor, depth + 1)
        for cnd, bdy in c.whiles:
            t = hc._trip_count(p, cnd)
            walk(bdy, factor * t, depth + 1)
            walk(cnd, factor * t, depth + 1)

    walk(entry, 1.0)
    return mult


def analyze_text(hlo: str, top: int = 20) -> None:
    p, rows = _collect_instrs(hlo)
    entry = p.entry or next(iter(p.comps))
    mult = _trip_multipliers(p, entry)

    agg = hc.aggregate(hlo)
    print(f"entry={entry}")
    print(f"flops={agg['flops']:.3e}  bytes={agg['bytes']:.3e}  "
          f"coll_wire={agg['collective_bytes']:.3e}")
    for k, v in sorted(agg["collectives"].items(),
                       key=lambda kv: -kv[1]["bytes"]):
        print(f"  {k:20s} wire={v['bytes']:.3e}  count={v['count']}")

    def series(title, key):
        print(f"\n--- top {top} by {title} (x trips) ---")
        ranked = sorted(
            ((key(r) * mult.get(r[0], 0.0), r) for r in rows
             if key(r) > 0 and mult.get(r[0], 0.0) > 0),
            key=lambda t: -t[0])[:top]
        for total, (comp, opcode, res, wire, flops, opn) in ranked:
            t = mult.get(comp, 0.0)
            print(f"{total:11.3e}  x{t:<6.0f} {opcode:20s} "
                  f"res={res:9.3e}  {opn[-90:]}")

    series("collective wire bytes", lambda r: r[3])
    series("memory bytes", lambda r: r[2])
    series("flops", lambda r: r[4])


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--pod-compress", default=None, choices=("u16", "u8"))
    ap.add_argument("--microbatch", type=int, default=None)
    ap.add_argument("--hlo", default=None, help="analyze a saved HLO file")
    ap.add_argument("--top", type=int, default=20)
    ap.add_argument("--save-hlo", default=None)
    args = ap.parse_args()

    if args.hlo:
        analyze_text(open(args.hlo).read(), args.top)
        return

    from repro.launch import dryrun
    rec, compiled = dryrun.compile_cell(args.arch, args.shape,
                                        args.multi_pod,
                                        pod_wire=args.pod_compress,
                                        microbatch=args.microbatch)
    hlo = compiled.as_text()
    if args.save_hlo:
        with open(args.save_hlo, "w") as f:
            f.write(hlo)
        print(f"wrote {args.save_hlo}")
    mem = rec.get("memory_analysis", {})
    print(f"live bytes/device: {mem.get('live_bytes_per_device', 0)/1e9:.2f} "
          f"GB")
    analyze_text(hlo, args.top)


if __name__ == "__main__":
    main()
