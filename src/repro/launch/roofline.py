"""Roofline term derivation from compiled dry-run artifacts.

Three terms per (arch × shape × mesh), in seconds (TPU v5e constants):

    compute    = HLO_FLOPs_per_device / peak_FLOPs          (197 TF/s bf16)
    memory     = HLO_bytes_per_device / HBM_bw               (819 GB/s)
    collective = collective_bytes_per_device / ICI_bw        (~50 GB/s/link)

``cost_analysis`` on the SPMD-partitioned module reports *per-device* flops
and bytes. Collective bytes are not in cost_analysis: we parse the
post-partition HLO and sum result-shape bytes of every collective op
(all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute).
That counts each op's per-device payload once — a conservative single-link
model; multi-link meshes only scale the constant, not the *shape* of the
analysis, and the hillclimb optimizes relative deltas.
"""
from __future__ import annotations

import re

HW = {
    "peak_flops_bf16": 197e12,   # per chip
    "hbm_bw": 819e9,             # per chip
    "ici_bw": 50e9,              # per link (single-link model)
}

# Backend-detected peak-bandwidth constants for the achieved-vs-peak
# scoreboard (benchmarks/bench_roofline.py). TPU: the v5e HBM constant
# above; GPU: a nominal HBM2e figure (A100-class — the scoreboard reports
# the source string so cross-machine comparisons stay honest). CPU has no
# meaningful nominal constant: peak_bandwidth() falls back to a measured
# STREAM-triad probe.
_PEAK_BW_CONSTANTS = {
    "tpu": ("constant:tpu_v5e_hbm", 819e9),
    "gpu": ("constant:gpu_hbm2e_nominal", 900e9),
}
_BW_CACHE: dict = {}


def stream_probe_bandwidth(elems: int = 8_000_000,
                           repeats: int = 7) -> float:
    """STREAM-triad-style achieved bandwidth (bytes/s) on the current
    backend: ``a = b + s·c`` over arrays far larger than cache, timed
    end-to-end (median of ``repeats``), counting 3 × 4 bytes per element
    (two streamed reads + one write — the classic STREAM convention).

    Shared containers get throttle windows lasting whole seconds, long
    enough to swallow every repeat of a single burst and poison the
    roofline denominator by an order of magnitude — so the probe runs
    two separated bursts and keeps the faster median."""
    import time

    import jax
    import jax.numpy as jnp
    import numpy as np

    b = jnp.arange(elems, dtype=jnp.float32)
    c = jnp.ones((elems,), jnp.float32)
    f = jax.jit(lambda b, c: b + 0.5 * c)
    best = 0.0
    for _ in range(2):
        jax.block_until_ready(f(b, c))     # compile + warm / re-warm
        ts = []
        for _ in range(repeats):
            t0 = time.perf_counter()
            jax.block_until_ready(f(b, c))
            ts.append(time.perf_counter() - t0)
        best = max(best, 3 * 4 * elems / float(np.median(ts)))
    return best


def peak_bandwidth(backend: str | None = None) -> dict:
    """``{backend, bw_bytes_per_s, source}`` — the denominator of the
    achieved-vs-peak fraction: a hardware constant on TPU/GPU, a measured
    STREAM probe elsewhere (CPU containers have no trustworthy nominal
    figure). Cached per backend — the probe costs ~0.5 s."""
    import jax

    backend = backend or jax.default_backend()
    ent = _BW_CACHE.get(backend)
    if ent is None:
        if backend in _PEAK_BW_CONSTANTS:
            src, bw = _PEAK_BW_CONSTANTS[backend]
        else:
            src, bw = "stream_probe", stream_probe_bandwidth()
        ent = _BW_CACHE[backend] = {
            "backend": backend, "bw_bytes_per_s": float(bw), "source": src}
    return dict(ent)

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# matches e.g. "f32[16,1024]" or "bf16[8,128]{1,0}"
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    b = _DTYPE_BYTES.get(dtype)
    if b is None:
        return 0
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * b


def collective_stats(hlo_text: str) -> dict:
    """Per-collective-type {bytes, count} from post-partition HLO text."""
    stats = {c: {"bytes": 0, "count": 0} for c in _COLLECTIVES}
    for line in hlo_text.splitlines():
        line = line.strip()
        m = re.match(r"%?[\w.\-]+\s*=\s*(.*)$", line)
        if not m:
            continue
        rhs = m.group(1)
        for coll in _COLLECTIVES:
            # opcode appears right after the result shape, before '('
            om = re.search(r"\)?\s(" + coll + r")\(", rhs) or \
                re.match(r"^\(?.*?\s" + coll + r"\(", rhs)
            if f" {coll}(" in rhs or rhs.startswith(coll + "("):
                # result shapes = all shapes before the opcode token
                head = rhs.split(coll + "(")[0]
                nbytes = sum(_shape_bytes(d, s)
                             for d, s in _SHAPE_RE.findall(head))
                # fusion/computation shapes can sneak in; result shape(s)
                # always lead the rhs, so cap at the leading tuple
                stats[coll]["bytes"] += nbytes
                stats[coll]["count"] += 1
                break
    total = sum(v["bytes"] for v in stats.values())
    stats["total_bytes"] = total
    return stats


def roofline_terms(cost: dict, coll_bytes: int, model_flops_global: float,
                   n_chips: int) -> dict:
    """cost: compiled.cost_analysis() dict (per-device)."""
    flops = float(cost.get("flops", 0.0))
    bytes_accessed = float(cost.get("bytes accessed", 0.0))
    t_compute = flops / HW["peak_flops_bf16"]
    t_memory = bytes_accessed / HW["hbm_bw"]
    t_coll = coll_bytes / HW["ici_bw"]
    dominant = max(
        (("compute", t_compute), ("memory", t_memory),
         ("collective", t_coll)), key=lambda kv: kv[1])[0]
    bound = max(t_compute, t_memory, t_coll)
    useful = model_flops_global / n_chips
    return {
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_collective_s": t_coll,
        "dominant": dominant,
        "hlo_flops_per_device": flops,
        "hlo_bytes_per_device": bytes_accessed,
        "collective_bytes_per_device": coll_bytes,
        "model_flops_per_device": useful,
        "useful_flops_ratio": useful / flops if flops else 0.0,
        # fraction of the roofline bound spent doing useful model math
        "roofline_fraction": (useful / HW["peak_flops_bf16"]) / bound
        if bound else 0.0,
    }


def model_flops(cfg, shape) -> float:
    """6·N·D (dense) / 6·N_active·D (MoE); decode counts one token/seq.

    N counts *matmul-participating* params: the input-embedding table is a
    gather (0 FLOPs) and is excluded; the LM-head matmul is included. For
    tied embeddings ``param_count`` already counts the table once (and it
    does participate in the head matmul), so no correction applies there.
    """
    n_active = cfg.active_param_count()
    if not cfg.tie_embeddings:
        n_active -= cfg.vocab * cfg.d_model   # input embedding: gather only
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    # decode: one token per sequence
    return 2.0 * n_active * shape.global_batch
