PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))
export PYTHONPATH

.PHONY: test verify verify-dist bench bench-spmv bench-dist

test:
	python -m pytest -x -q

# tier-1 tests + tiny-scale spmv benchmark smoke (what CI runs)
verify:
	bash scripts/ci.sh

# distributed layer: tests under 8 simulated host devices + a 4-device
# PCG smoke (the device count must be fixed before JAX initializes)
verify-dist:
	XLA_FLAGS="--xla_force_host_platform_device_count=8" \
		python -m pytest -x -q tests/test_distributed.py \
		tests/test_distributed_properties.py
	XLA_FLAGS="--xla_force_host_platform_device_count=4" \
		python examples/distributed_pcg.py --side 8

bench:
	python -m benchmarks.run

# regenerate the checked-in perf-trajectory file (small scale)
bench-spmv:
	python -m benchmarks.run --only spmv --scale small

# regenerate the checked-in distributed scaling curve (small scale)
bench-dist:
	python -m benchmarks.run --only distributed --scale small
