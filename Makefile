PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))
export PYTHONPATH

.PHONY: test verify verify-dist verify-precision verify-composite \
	verify-fused verify-pallas verify-robust verify-observe \
	verify-serving bench bench-spmv bench-dist bench-precision \
	bench-composite bench-robust bench-roofline bench-memory \
	bench-e8my bench-serving perf-gate perf-baseline

test:
	python -m pytest -x -q

# tier-1 tests + tiny-scale spmv benchmark smoke (what CI runs)
verify:
	bash scripts/ci.sh

# distributed layer: tests under 8 simulated host devices + a 4-device
# PCG smoke (the device count must be fixed before JAX initializes)
verify-dist:
	XLA_FLAGS="--xla_force_host_platform_device_count=8" \
		python -m pytest -x -q tests/test_distributed.py \
		tests/test_distributed_properties.py
	XLA_FLAGS="--xla_force_host_platform_device_count=4" \
		python examples/distributed_pcg.py --side 8

# adaptive precision subsystem: selection/mixed/store tests + an
# adaptive_pcg smoke (must hit 1e-8 with a low-precision preconditioner)
verify-precision:
	python -m pytest -x -q tests/test_precision.py tests/test_codec_edges.py
	python examples/mixed_precision_solver.py --nx 6

# fused checkpoint decode (DESIGN.md §10): decode-path equivalence
# properties, Pallas interpret parity for the checkpoint kernels (the
# band/full variants benchmarks never exercise), the steady-state
# trace-count regression guard, and the fused solver step
verify-fused:
	python -m pytest -x -q tests/test_fused.py

# fused-stream Pallas kernel (DESIGN.md §14): interpret-mode bit-parity
# vs the jnp fused decode (codec × wr × boundary sweeps), the 'fused'
# plan variant (policy, spmm fallback, retile wr rebuild), backend-keyed
# retile store entries and the fused-variant solver parity — under every
# cursor-cache mode (the fused variant must force 'checkpoint' and log
# the override in plan.policy)
verify-pallas:
	for mode in checkpoint full 0; do \
		echo "-- REPRO_PLAN_CURSOR_CACHE=$$mode"; \
		REPRO_PLAN_CURSOR_CACHE=$$mode \
			python -m pytest -x -q tests/test_fused_kernel.py \
			|| exit 1; \
	done

# block-composition engine: composite/kind-parser/warmup tests plus the
# mesh-gated dist_mixed × adaptive_pcg_dist acceptance tests under 4
# simulated devices
verify-composite:
	XLA_FLAGS="--xla_force_host_platform_device_count=4" \
		python -m pytest -x -q tests/test_composite.py \
		tests/test_composite_properties.py

# guarded execution (DESIGN.md §11): guard/inject/recover unit+property
# tests, the distributed fault cases under 8 simulated devices, and a
# tiny-scale injection-campaign + recovery benchmark smoke
verify-robust:
	python -m pytest -x -q tests/test_robust.py
	XLA_FLAGS="--xla_force_host_platform_device_count=8" \
		python -m pytest -x -q tests/test_robust.py -k "dist"
	python -m benchmarks.run --only robust --scale tiny

# flight recorder (DESIGN.md §12): registry + parity + serving tests
# with the recorder ON (tier-1 runs them with it off), the dist parity
# case under 4 simulated devices, and the <3% dispatch-overhead gate
verify-observe:
	REPRO_OBS=1 python -m pytest -x -q tests/test_observe.py
	XLA_FLAGS="--xla_force_host_platform_device_count=4" \
		python -m pytest -x -q tests/test_observe.py -k "dist"
	python scripts/check_observe_overhead.py

# serving front end (DESIGN.md §15): policy/frontend semantics on the
# manual clock, the inject.py chaos campaigns (breaker open -> fallback
# -> rebuild -> re-close; zero out-of-budget deliveries), and a
# tiny-scale open-loop Poisson bench smoke
verify-serving:
	python -m pytest -x -q tests/test_serving.py tests/test_serving_chaos.py
	REPRO_BENCH_SERVING_JSON=/tmp/BENCH_serving_smoke.json \
		REPRO_OBS_ARCHIVE_DIR="" \
		python -m benchmarks.run --only serving --scale tiny

bench:
	python -m benchmarks.run

# regenerate the checked-in perf-trajectory file (small scale)
bench-spmv:
	python -m benchmarks.run --only spmv --scale small

# regenerate the checked-in distributed scaling curve (small scale)
bench-dist:
	python -m benchmarks.run --only distributed --scale small

# regenerate the checked-in accuracy/throughput frontier (small scale)
bench-precision:
	python -m benchmarks.run --only precision --scale small

# regenerate the checked-in dist-mixed vs dist-fp32 PCG curve (small scale)
bench-composite:
	python -m benchmarks.run --only composite --scale small

# regenerate the checked-in guard overhead/detection/recovery file
# (small scale)
bench-robust:
	python -m benchmarks.run --only robust --scale small

# regenerate the checked-in roofline scoreboard (tiny suite × codecs,
# achieved-vs-peak + HLO cross-check + embedded observe report)
bench-roofline:
	python -m benchmarks.run --only roofline --scale tiny

# regenerate the checked-in memory-footprint ratios (small scale)
bench-memory:
	python -m benchmarks.run --only memory --scale small

# regenerate the checked-in E8MY D-sweep (small scale)
bench-e8my:
	python -m benchmarks.run --only e8my --scale small

# regenerate the checked-in serving QPS/latency/shed trace (small scale)
bench-serving:
	python -m benchmarks.run --only serving --scale small

# perf sentinel (DESIGN.md §13.3): gate the working tree against the
# committed noise-aware baseline — runs the gated benches (spmv +
# roofline) at tiny scale in a temp dir and compares paired medians
perf-gate:
	python scripts/check_perf_regression.py \
		--against artifacts/perf_baseline.json

# refresh the committed baseline (3 repeated tiny-scale runs -> median
# + IQR per gated metric); commit artifacts/perf_baseline.json after
perf-baseline:
	python scripts/check_perf_regression.py \
		--make-baseline artifacts/perf_baseline.json --reps 3
