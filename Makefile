PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))
export PYTHONPATH

.PHONY: test verify bench bench-spmv

test:
	python -m pytest -x -q

# tier-1 tests + tiny-scale spmv benchmark smoke (what CI runs)
verify:
	bash scripts/ci.sh

bench:
	python -m benchmarks.run

# regenerate the checked-in perf-trajectory file (small scale)
bench-spmv:
	python -m benchmarks.run --only spmv --scale small
