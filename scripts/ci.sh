#!/usr/bin/env bash
# Tier-1 verification: the full test suite plus a benchmark smoke run.
#   scripts/ci.sh          # tests + tiny spmv bench smoke
#   scripts/ci.sh fast     # tests only
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== tier-1: pytest =="
python -m pytest -x -q

if [[ "${1:-}" != "fast" ]]; then
  echo "== distributed: tests under 8 simulated host devices =="
  XLA_FLAGS="${XLA_FLAGS:-} --xla_force_host_platform_device_count=8" \
    python -m pytest -x -q tests/test_distributed.py \
    tests/test_distributed_properties.py

  echo "== smoke: 4-device distributed PCG =="
  XLA_FLAGS="${XLA_FLAGS:-} --xla_force_host_platform_device_count=4" \
    python examples/distributed_pcg.py --side 8

  echo "== composite: block-composition engine + dist_mixed acceptance =="
  # tests the shared CompositePlan layer (mixed/dist wrappers, kind
  # parser, WarmupSpec) and — under 4 simulated devices — that a
  # dist_mixed budget drives adaptive_pcg_dist to 1e-8 with iteration
  # counts identical to the single-device solver
  XLA_FLAGS="${XLA_FLAGS:-} --xla_force_host_platform_device_count=4" \
    python -m pytest -x -q tests/test_composite.py \
    tests/test_composite_properties.py

  echo "== fused: checkpoint decode equivalence + trace-count guard =="
  # the fused ragged checkpoint path (DESIGN.md §10): every decode-cache
  # mode must match the numpy oracle bit-for-bit on integer data, the
  # checkpoint-seeded Pallas kernels must match the legacy carry kernels
  # in interpret mode (band/full variants otherwise never run in CI), a
  # steady-state matvec must stay ONE jitted dispatch across 10 calls,
  # and the fused solver step must not change iteration counts. Run the
  # whole file under each cursor-cache mode so the default-plan override
  # paths ('full' build-time cols, '0' runtime scan) stay green too.
  for mode in checkpoint full 0; do
    echo "   -- REPRO_PLAN_CURSOR_CACHE=$mode"
    REPRO_PLAN_CURSOR_CACHE="$mode" python -m pytest -x -q tests/test_fused.py
  done

  echo "== pallas: fused-stream kernel parity (all cursor-cache modes) =="
  # the fused-stream Pallas kernel (DESIGN.md §14) must match the jnp
  # fused decode bit-for-bit in interpret mode — codec × wr × boundary
  # sweeps, the 'fused' plan variant plumbing (spmm fallback, retile wr
  # rebuild, backend-keyed store entries) and solver iteration parity.
  # The fused variant pins decode_cache='checkpoint' internally, so the
  # mode loop proves the override logs and stays correct under each env.
  for mode in checkpoint full 0; do
    echo "   -- REPRO_PLAN_CURSOR_CACHE=$mode"
    REPRO_PLAN_CURSOR_CACHE="$mode" \
      python -m pytest -x -q tests/test_fused_kernel.py
  done

  echo "== robust: guard/inject/recover + dist fault cases =="
  # guarded execution (DESIGN.md §11): checksum + ABFT detection under
  # seeded injection, store quarantine, cache-bound regression, and the
  # self-healing solve; the dist fault cases need 8 simulated devices
  python -m pytest -x -q tests/test_robust.py
  XLA_FLAGS="${XLA_FLAGS:-} --xla_force_host_platform_device_count=8" \
    python -m pytest -x -q tests/test_robust.py -k "dist"

  echo "== observe: flight recorder ON + overhead gate =="
  # the whole observe suite runs with the recorder ENABLED (tier-1 above
  # already ran it with the recorder off — both states must stay green;
  # the parity tests prove REPRO_OBS=1 changes no solver results
  # bit-for-bit), then the dispatch-path cost gate: recorder overhead on
  # a steady-state spmv loop must stay under 3%
  REPRO_OBS=1 python -m pytest -x -q tests/test_observe.py
  python scripts/check_observe_overhead.py

  echo "== sentinel: exporters + trajectory + overhead with live exporter =="
  # the perf-sentinel layer (DESIGN.md §13): Prometheus/JSONL exporter
  # round-trips, trajectory schema contract, gate statistics, span
  # profiling — then the same <3% dispatch-overhead gate re-run with a
  # live 1s-interval exporter thread flushing throughout
  REPRO_OBS=1 python -m pytest -x -q tests/test_sentinel.py
  python scripts/check_observe_overhead.py --with-exporter

  echo "== serving: frontend/policy tests + chaos campaigns =="
  # the resilient serving front end (DESIGN.md §15): admission/backoff/
  # breaker/degradation semantics on the manual clock, coalesced multi-
  # RHS bit-exactness, exporter lifecycle — then the inject.py chaos
  # campaigns including the acceptance trace (2x-capacity overload +
  # 50-injection campaign: zero out-of-budget deliveries, >=70% goodput,
  # breaker recovery)
  python -m pytest -x -q tests/test_serving.py tests/test_serving_chaos.py

  echo "== precision: subsystem tests + adaptive_pcg smoke =="
  # the example's adaptive section must converge to 1e-8 with a
  # low-precision (sub-32-bit) operator/preconditioner; the store
  # round-trips under a tmpdir inside the pytest run
  python -m pytest -x -q tests/test_precision.py tests/test_codec_edges.py
  python examples/mixed_precision_solver.py --nx 6 | tee /tmp/adaptive_smoke.txt
  grep -q "sub-32-bit matvecs" /tmp/adaptive_smoke.txt

  echo "== smoke: benchmarks (spmv + robust + roofline, tiny scale) =="
  # writes artifacts/bench_results.json plus BENCH_spmv.json,
  # BENCH_robust.json and BENCH_roofline.json; the smoke JSONs are
  # artifacts only — the checked-in files are regenerated deliberately
  # (make bench-spmv / bench-robust / bench-roofline), so restore them
  # afterwards.
  for f in BENCH_spmv.json BENCH_robust.json BENCH_roofline.json; do
    cp "$f" "/tmp/$f.orig" 2>/dev/null || true
  done
  python -m benchmarks.run --only spmv,robust,roofline --scale tiny

  echo "== sentinel: perf regression gate on the smoke artifacts =="
  # the tiny smoke run just produced BENCH_spmv/roofline at the SAME
  # scale as the committed baseline — gate them before restoring the
  # checked-in files (a failure here means the working tree made the
  # hot path slower than artifacts/perf_baseline.json tolerates)
  python scripts/check_perf_regression.py \
    --against artifacts/perf_baseline.json \
    --bench BENCH_spmv.json BENCH_roofline.json

  for f in BENCH_spmv.json BENCH_robust.json BENCH_roofline.json; do
    if [[ -f "/tmp/$f.orig" ]]; then mv "/tmp/$f.orig" "$f"; fi
  done
fi

echo "== ci.sh: OK =="
