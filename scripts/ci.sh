#!/usr/bin/env bash
# Tier-1 verification: the full test suite plus a benchmark smoke run.
#   scripts/ci.sh          # tests + tiny spmv bench smoke
#   scripts/ci.sh fast     # tests only
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== tier-1: pytest =="
python -m pytest -x -q

if [[ "${1:-}" != "fast" ]]; then
  echo "== distributed: tests under 8 simulated host devices =="
  XLA_FLAGS="${XLA_FLAGS:-} --xla_force_host_platform_device_count=8" \
    python -m pytest -x -q tests/test_distributed.py \
    tests/test_distributed_properties.py

  echo "== smoke: 4-device distributed PCG =="
  XLA_FLAGS="${XLA_FLAGS:-} --xla_force_host_platform_device_count=4" \
    python examples/distributed_pcg.py --side 8

  echo "== composite: block-composition engine + dist_mixed acceptance =="
  # tests the shared CompositePlan layer (mixed/dist wrappers, kind
  # parser, WarmupSpec) and — under 4 simulated devices — that a
  # dist_mixed budget drives adaptive_pcg_dist to 1e-8 with iteration
  # counts identical to the single-device solver
  XLA_FLAGS="${XLA_FLAGS:-} --xla_force_host_platform_device_count=4" \
    python -m pytest -x -q tests/test_composite.py \
    tests/test_composite_properties.py

  echo "== fused: checkpoint decode equivalence + trace-count guard =="
  # the fused ragged checkpoint path (DESIGN.md §10): every decode-cache
  # mode must match the numpy oracle bit-for-bit on integer data, the
  # checkpoint-seeded Pallas kernels must match the legacy carry kernels
  # in interpret mode (band/full variants otherwise never run in CI), a
  # steady-state matvec must stay ONE jitted dispatch across 10 calls,
  # and the fused solver step must not change iteration counts
  python -m pytest -x -q tests/test_fused.py

  echo "== precision: subsystem tests + adaptive_pcg smoke =="
  # the example's adaptive section must converge to 1e-8 with a
  # low-precision (sub-32-bit) operator/preconditioner; the store
  # round-trips under a tmpdir inside the pytest run
  python -m pytest -x -q tests/test_precision.py tests/test_codec_edges.py
  python examples/mixed_precision_solver.py --nx 6 | tee /tmp/adaptive_smoke.txt
  grep -q "sub-32-bit matvecs" /tmp/adaptive_smoke.txt

  echo "== smoke: benchmarks (spmv, tiny scale) =="
  # writes artifacts/bench_results.json and BENCH_spmv.json; the tiny-scale
  # JSON is a smoke artifact only — the checked-in BENCH_spmv.json is
  # regenerated at small scale (make bench-spmv), so restore it afterwards.
  cp BENCH_spmv.json /tmp/BENCH_spmv.json.orig 2>/dev/null || true
  python -m benchmarks.run --only spmv --scale tiny
  if [[ -f /tmp/BENCH_spmv.json.orig ]]; then
    mv /tmp/BENCH_spmv.json.orig BENCH_spmv.json
  fi
fi

echo "== ci.sh: OK =="
