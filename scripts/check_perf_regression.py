#!/usr/bin/env python
"""Noise-aware benchmark regression gate (DESIGN.md §13.3).

Two modes, both built on :mod:`repro.observe.trajectory`:

    # refresh the committed baseline (N repeated tiny-scale runs)
    python scripts/check_perf_regression.py \
        --make-baseline artifacts/perf_baseline.json --reps 3

    # gate the working tree against it (what `make perf-gate` / ci.sh run)
    python scripts/check_perf_regression.py \
        --against artifacts/perf_baseline.json

The gated benches (spmv + roofline, the hot-path timings) run at TINY
scale in a subprocess with their output redirected to a temp dir via the
``REPRO_BENCH_*_JSON`` env vars, so the checked-in small-scale BENCH
files are never clobbered.  Pass ``--bench FILE...`` to gate
already-produced BENCH files instead of re-running (ci.sh does this with
its smoke artifacts).

Every gated run is also appended to ``artifacts/trajectory.jsonl`` — the
unified perf history — unless ``--trajectory ''`` disables it.

Exit code: 0 = gate passed (or baseline written), 1 = regression.
"""
from __future__ import annotations

import argparse
import os
import subprocess
import sys
import tempfile

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(_ROOT, "src"))
sys.path.insert(0, _ROOT)

from repro.observe import trajectory  # noqa: E402

#: benches that produce GATED_METRICS rows, with their redirect env var
_GATED_BENCHES = (
    ("spmv", "REPRO_BENCH_SPMV_JSON"),
    ("roofline", "REPRO_BENCH_ROOFLINE_JSON"),
)


def run_gated_benches(outdir: str, tag: str = "run") -> list[str]:
    """One tiny-scale run of the gated benches, outputs redirected into
    ``outdir``/``tag`` (canonical BENCH_<name>.json filenames — the
    trajectory keys on the filename); returns the produced paths."""
    env = dict(os.environ)
    paths = []
    os.makedirs(os.path.join(outdir, tag), exist_ok=True)
    for name, var in _GATED_BENCHES:
        p = os.path.join(outdir, tag, f"BENCH_{name}.json")
        env[var] = p
        paths.append(p)
    env["REPRO_OBS_ARCHIVE_DIR"] = ""        # no telemetry spam from reps
    src = os.path.join(_ROOT, "src")
    env["PYTHONPATH"] = src + (os.pathsep + env["PYTHONPATH"]
                               if env.get("PYTHONPATH") else "")
    only = ",".join(name for name, _ in _GATED_BENCHES)
    subprocess.run(
        [sys.executable, "-m", "benchmarks.run", "--only", only,
         "--scale", "tiny"],
        cwd=_ROOT, env=env, check=True,
        stdout=subprocess.DEVNULL)
    return [p for p in paths if os.path.exists(p)]


def _report(res: dict) -> None:
    bm = res["baseline_meta"]
    print(f"[perf-gate] baseline: sha={bm.get('git_sha', '?')} "
          f"scale={bm.get('scale', '?')} reps={bm.get('reps', '?')}")
    print(f"[perf-gate] thresholds: rel_tol={res['rel_tol']} "
          f"iqr_k={res['iqr_k']} severe_tol={res['severe_tol']} "
          f"min_classes={res['min_classes']}")
    for row in res["checked"]:
        mark = "SEVERE" if row["severe"] else (
            "regressed" if row["regressed"] else "ok")
        arrow = "<=" if row["direction"] == "lower" else ">="
        print(f"  [{mark:>9}] {row['key']:<55} "
              f"base={row['baseline']:.4g} cur={row['current']:.4g} "
              f"({arrow} better) regression={row['regression']:+.1%} "
              f"threshold={row['threshold']:.1%}")
    for row in res["skipped"]:
        print(f"  [  skipped] {row['key']:<55} {row['reason']}")
    if res["regressed_classes"]:
        print(f"[perf-gate] regressed classes: "
              f"{', '.join(res['regressed_classes'])} "
              f"(fail at >= {res['min_classes']})")
    print(f"[perf-gate] {'PASS' if res['ok'] else 'FAIL'}: "
          f"{len(res['checked'])} checked, "
          f"{len(res['regressed'])} regressed, "
          f"{len(res['severe'])} severe, {len(res['skipped'])} skipped")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description=__doc__.splitlines()[0],
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("--against", default=None, metavar="BASELINE",
                    help="gate mode: committed baseline JSON to compare "
                         "against")
    ap.add_argument("--make-baseline", default=None, metavar="PATH",
                    help="baseline mode: run --reps repetitions and write "
                         "the reduced baseline here")
    ap.add_argument("--reps", type=int, default=3,
                    help="baseline repetitions (default 3)")
    ap.add_argument("--bench", nargs="*", default=None, metavar="FILE",
                    help="gate these BENCH_*.json files instead of "
                         "running the tiny benches")
    ap.add_argument("--trajectory", default="artifacts/trajectory.jsonl",
                    help="unified perf-history JSONL ('' disables)")
    ap.add_argument("--rel-tol", type=float, default=0.25)
    ap.add_argument("--iqr-k", type=float, default=3.0)
    ap.add_argument("--severe-tol", type=float, default=0.75)
    ap.add_argument("--min-classes", type=int, default=2)
    args = ap.parse_args(argv)
    if bool(args.against) == bool(args.make_baseline):
        ap.error("exactly one of --against / --make-baseline is required")

    traj = args.trajectory
    if traj and not os.path.isabs(traj):
        traj = os.path.join(_ROOT, traj)

    if args.make_baseline:
        runs = []
        with tempfile.TemporaryDirectory(prefix="perf_baseline_") as td:
            for i in range(args.reps):
                print(f"[perf-baseline] rep {i + 1}/{args.reps} "
                      "(tiny-scale gated benches)...")
                files = run_gated_benches(td, tag=f"rep{i}")
                runs.append(trajectory.ingest_many(files))
        base = trajectory.build_baseline(runs)
        trajectory.save_baseline(base, args.make_baseline)
        print(f"[perf-baseline] wrote {args.make_baseline}: "
              f"{len(base['entries'])} entries, reps={args.reps}")
        if traj:
            n = trajectory.append([r for run in runs for r in run], traj)
            print(f"[perf-baseline] appended {n} records -> {traj}")
        return 0

    baseline = trajectory.load_baseline(args.against)
    if args.bench:
        current = trajectory.ingest_many(args.bench)
    else:
        with tempfile.TemporaryDirectory(prefix="perf_gate_") as td:
            print("[perf-gate] running tiny-scale gated benches...")
            current = trajectory.ingest_many(
                run_gated_benches(td, tag="gate"))
    res = trajectory.gate(
        current, baseline, rel_tol=args.rel_tol, iqr_k=args.iqr_k,
        severe_tol=args.severe_tol, min_classes=args.min_classes)
    _report(res)
    if traj:
        n = trajectory.append(current, traj)
        print(f"[perf-gate] appended {n} records -> {traj}")
    return 0 if res["ok"] else 1


if __name__ == "__main__":
    raise SystemExit(main())
