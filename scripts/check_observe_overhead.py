"""CI gate: the flight recorder's dispatch-path cost (DESIGN.md §12.5).

Times a steady-state plan-dispatch SpMV loop with the recorder off and
on, INTERLEAVED (:func:`benchmarks.common.time_fns`, so container noise
cancels out of the ratio), and fails when the enabled recorder costs
more than ``--budget`` percent (default 3).  The instrumented work per
dispatch is one dict lookup plus a prebuilt lock-free counter bump —
the per-plan byte figures are derived once and cached in ``plan._fns``
— so the budget holds with a wide margin on any healthy build.

    PYTHONPATH=src python scripts/check_observe_overhead.py

``--with-exporter`` runs the same measurement with a live 1s-interval
JSONL exporter thread (``observe.export.start_exporter``) flushing to a
temp file throughout — proving the §13 egress layer stays inside the
same budget (the exporter only *reads* snapshots, so its cost is a
periodic lock + copy off the dispatch path).
"""
from __future__ import annotations

import argparse
import os
import sys

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

import jax                                                   # noqa: E402
import jax.numpy as jnp                                      # noqa: E402
import numpy as np                                           # noqa: E402

from benchmarks import common                                # noqa: E402
from repro import observe                                    # noqa: E402
from repro.core import packsell as pk                        # noqa: E402
from repro.core import testmats                              # noqa: E402
from repro.kernels import plan as kplan                      # noqa: E402

#: calls per timing sample: the recorder cost is ~1.5us against a
#: ~100us dispatch, so each sample averages a burst; the whole on+off
#: round stays far shorter than a container throttle window, so both
#: arms of each paired ratio see the same machine state
REPS = 20


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--budget", type=float, default=3.0,
                    help="max recorder overhead in percent")
    ap.add_argument("--rounds", type=int, default=75)
    ap.add_argument("--with-exporter", action="store_true",
                    help="measure with a live 1s JSONL exporter thread")
    args = ap.parse_args()

    exporter = None
    if args.with_exporter:
        import tempfile

        from repro.observe import export
        path = os.path.join(tempfile.mkdtemp(prefix="repro_obs_"),
                            "overhead.jsonl")
        exporter = export.start_exporter(interval_s=1.0, path=path)
        print(f"exporter: live, interval=1.0s -> {path}")

    a = testmats.stencil_1d(16384, 3)
    mat = pk.from_csr(a, C=32, sigma=256, D=15, codec="fp16")
    plan = kplan.get_plan(mat)
    x = jnp.asarray(
        np.random.default_rng(0).standard_normal(mat.m).astype(np.float32))
    jax.block_until_ready(plan.spmv(mat, x))     # compile once for both

    def burst(v, on):
        prev = observe.enable(on)
        try:
            for _ in range(REPS - 1):
                plan.spmv(mat, v)
            return plan.spmv(mat, v)
        finally:
            observe.enable(prev)

    def measure():
        prev = observe.enable(False)
        try:
            ts = common.time_fns(
                {"off": lambda v: burst(v, False),
                 "on": lambda v: burst(v, True)},
                {"off": (x,), "on": (x,)},
                warmup=3, rounds=args.rounds, samples=True)
        finally:
            observe.enable(prev)
            observe.reset()
        ratio = common.paired_speedup(ts, "on", "off")   # t_on / t_off
        return (ratio - 1.0) * 100.0, ts

    try:
        for attempt in (1, 2):       # one re-measure absorbs a throttle
            overhead, ts = measure()  # window that swallowed a whole run
            t_off = float(np.median(ts["off"])) / REPS * 1e6
            t_on = float(np.median(ts["on"])) / REPS * 1e6
            print(f"observe overhead: off={t_off:.2f}us on={t_on:.2f}us "
                  f"per dispatch -> {overhead:+.2f}% "
                  f"(budget {args.budget:.1f}%, attempt {attempt}"
                  f"{', exporter live' if exporter else ''})")
            if overhead <= args.budget:
                print("OK")
                return 0
        print("FAIL: recorder overhead exceeds budget", file=sys.stderr)
        return 1
    finally:
        if exporter is not None:
            exporter.stop()
            print(f"exporter: {exporter.flushes} flushes, clean stop")


if __name__ == "__main__":
    sys.exit(main())
