"""Distributed PackSELL quickstart: partitioned SpMV + multi-device PCG.

Run with simulated host devices (the device count must be set before JAX
initializes — do it on the command line, not in code):

    XLA_FLAGS=--xla_force_host_platform_device_count=4 \
        PYTHONPATH=src python examples/distributed_pcg.py

The flow is the whole distributed story in four lines:

    dplan = build_dist_plan(a, codec="fp16")       # partition + halo maps
    y     = dplan.spmv(x)                          # one shard_map dispatch
    x, info = cg.jacobi_pcg_dist(dplan, a.diagonal(), b)   # sharded solve

Everything else below is verification and reporting.
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

jax.config.update("jax_enable_x64", True)

from repro.core import packsell, testmats                     # noqa: E402
from repro.distributed import build_dist_plan                 # noqa: E402
from repro.solvers import cg                                  # noqa: E402
from repro.solvers import operators as op                     # noqa: E402


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--side", type=int, default=10,
                    help="HPCG grid side (n = side^3 rows)")
    ap.add_argument("--codec", default="fp16",
                    help="value codec: fp16 | bf16 | e8m | fixed<F>")
    ap.add_argument("--dwidth", type=int, default=15, help="delta width D")
    ap.add_argument("--tol", type=float, default=1e-7)
    args = ap.parse_args()

    n_dev = jax.device_count()
    print(f"devices: {n_dev} ({jax.default_backend()})")

    a = testmats.hpcg(args.side, args.side, args.side)
    s, _ = op.sym_scale(a)
    n = s.shape[0]
    print(f"matrix: HPCG {args.side}^3 -> n={n}, nnz={s.nnz}")

    # one shard per device: row-block partition, per-partition σ-sort,
    # halo maps, jitted shard_map dispatch
    dplan = build_dist_plan(s, C=32, sigma=256, D=args.dwidth,
                            codec=args.codec)
    st = dplan.memory_stats()
    print(f"shards: {dplan.n_shards}, halo entries: {st['halo_entries']} "
          f"({st['halo_entries'] / max(n, 1):.1%} of x), "
          f"bytes/shard: {st['min_shard_bytes']}..{st['max_shard_bytes']}")

    # distributed SpMV matches the single-device plan engine
    rng = np.random.default_rng(0)
    x = rng.standard_normal(n).astype(np.float32)
    y_dist = np.asarray(dplan.spmv(x))
    mat = packsell.from_csr(s, C=32, sigma=256, D=args.dwidth,
                            codec=args.codec)
    y_one = np.asarray(packsell.packsell_spmv_jnp(mat, jnp.asarray(x)))
    err = np.max(np.abs(y_dist - y_one)) / max(np.max(np.abs(y_one)), 1e-30)
    print(f"spmv max rel diff vs single device: {err:.2e}")
    assert err < 1e-5, "distributed SpMV diverged from single device"

    # distributed Jacobi-PCG: whole solve inside one shard_map region
    b = jnp.asarray(rng.standard_normal(n))
    x_sol, info = cg.jacobi_pcg_dist(dplan, s.diagonal(), b, tol=args.tol,
                                     maxiter=500, dtype=jnp.float64)
    r = np.asarray(b, np.float64) - s @ np.asarray(x_sol, np.float64)
    true_res = np.linalg.norm(r) / np.linalg.norm(np.asarray(b))
    print(f"pcg: {int(info.iters)} iters, recurrence relres "
          f"{float(info.relres):.2e}, true relres {true_res:.2e} "
          f"(floors at the {args.codec} quantization error)")
    assert float(info.relres) < args.tol, "PCG did not converge"
    print("OK")


if __name__ == "__main__":
    main()
