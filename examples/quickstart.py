"""Quickstart: build a PackSELL matrix, run SpMV three ways, solve a system.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

jax.config.update("jax_enable_x64", True)

from repro.core import packsell, sell, testmats            # noqa: E402
from repro.kernels import ops                              # noqa: E402
from repro.solvers import precond                          # noqa: E402
from repro.solvers.cg import pcg                           # noqa: E402
from repro.solvers.operators import OperatorSet, sym_scale  # noqa: E402


def main():
    # 1) a sparse matrix — the HPCG 27-point stencil (paper §5.2 suite)
    a = testmats.hpcg(12, 12, 12)
    n = a.shape[0]
    print(f"matrix: HPCG 12x12x12, n={n}, nnz={a.nnz}")

    # 2) PackSELL with the paper's FP16 embed (W=32, V=16, D=15)
    A = packsell.from_csr(a, C=128, sigma=256, D=15, codec="fp16")
    S = sell.from_csr(a, C=128, sigma=256, value_dtype="float16")
    ms, ss = A.memory_stats(), S.memory_stats()
    print(f"PackSELL bytes: {ms['packsell_bytes']:,}  "
          f"SELL bytes: {ss['sell_bytes']:,}  "
          f"ratio: {ms['packsell_bytes'] / ss['sell_bytes']:.3f} "
          f"(paper lower bound 0.667), dummies: {A.n_dummy}")

    # 3) SpMV: vectorized jnp path vs the Pallas TPU kernel (interpret mode
    #    on CPU) vs an fp64 oracle
    x = jnp.asarray(np.random.default_rng(0).standard_normal(n))
    y_jnp = A.spmv(x.astype(jnp.float32))
    y_pallas = ops.packsell_spmv(A, x.astype(jnp.float32))
    y_exact = a @ np.asarray(x)
    print(f"jnp vs pallas max |Δ|: "
          f"{float(jnp.max(jnp.abs(y_jnp - y_pallas))):.2e}")
    rel = np.linalg.norm(np.asarray(y_jnp) - y_exact) / \
        np.linalg.norm(y_exact)
    print(f"fp16-quantized SpMV rel. error vs fp64: {rel:.2e}")

    # 4) the paper's end game: a mixed-precision solve. FP64 PCG with an
    #    approximate inverse applied through *PackSELL E8M14* SpMV.
    a_s, _ = sym_scale(a)
    ops_set = OperatorSet(a_s, C=32, sigma=256)
    A16 = ops_set.matvec("packsell_e8m8")        # E8M14 values (D=8)
    M = precond.neumann_ainv(ops_set.diag(), A16, k=2, dtype=jnp.float32)
    b = jnp.ones((n,), jnp.float64)
    x_sol, info = pcg(ops_set.matvec("fp64"), b, M=M, tol=1e-9,
                      maxiter=500, dtype=jnp.float64)
    print(f"PCG + PackSELL-E8M14 preconditioner: {int(info.iters)} iters, "
          f"relres {float(info.relres):.2e}")


if __name__ == "__main__":
    main()
