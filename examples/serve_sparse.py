"""Serving example: continuous-batching decode engine + PackSELL
pruned-weight linear (the paper's SpMV in the decode path).

    PYTHONPATH=src python examples/serve_sparse.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.models import transformer as tfm
from repro.models.sparse_linear import PackSELLLinear
from repro.serving import DecodeEngine, ServeConfig


def main():
    cfg = configs.reduce(configs.get("granite-3-2b"))
    params, _ = tfm.init_params(cfg, jax.random.PRNGKey(0))

    # --- 1) batched serving with continuous batching ---------------------
    eng = DecodeEngine(cfg, params, ServeConfig(slots=4, max_len=96))
    eng.warmup()        # compile the pool decode step before traffic lands
    rng = np.random.default_rng(0)
    for _ in range(8):
        eng.submit(rng.integers(1, cfg.vocab, size=int(rng.integers(4, 12))),
                   max_new_tokens=8)
    done = eng.run()
    st = eng.stats()
    print(f"served {st['requests']} requests, {st['tokens']} tokens, "
          f"{st['tokens_per_s']:.1f} tok/s, "
          f"mean TTFT {st['mean_ttft_s'] * 1e3:.0f} ms")

    # --- 2) PackSELL pruned-weight decode matvec --------------------------
    # decode is memory-bound: bytes-streamed-per-token is the cost. Take the
    # model's largest projection (the LM head) and compare dense bf16
    # streaming vs PackSELL at 30% density with the bf16 embed codec.
    w = np.asarray(params["head"]["w"], np.float32)      # [d, vocab]
    lin = PackSELLLinear.from_dense(w, density=0.3, codec="bf16", D=15,
                                    C=128, sigma=256)
    x = np.asarray(jax.random.normal(jax.random.PRNGKey(1),
                                     (cfg.d_model,)), np.float32)
    y_sparse = lin(jnp.asarray(x))
    y_dense = jnp.asarray(x) @ jnp.asarray(w)
    dense_bf16_bytes = w.size * 2
    sp = lin.decode_bytes_per_token()
    print(f"\nLM head [{w.shape[0]}x{w.shape[1]}]: dense bf16 "
          f"{dense_bf16_bytes:,} B/token vs PackSELL(30%) {sp:,} B/token "
          f"-> {dense_bf16_bytes / sp:.2f}x less decode traffic")
    # top-k agreement dense vs pruned (quality proxy)
    k = 10
    top_d = np.argsort(-np.asarray(y_dense))[:k]
    top_s = np.argsort(-np.asarray(y_sparse))[:k]
    print(f"top-{k} overlap dense vs pruned: "
          f"{len(set(top_d.tolist()) & set(top_s.tolist()))}/{k}")


if __name__ == "__main__":
    main()
