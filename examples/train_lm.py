"""End-to-end training driver: synthetic-language LM with the full stack —
data pipeline, AdamW+ZeRO specs, checkpointing, straggler monitor,
preemption-safe loop, optional E8MY gradient compression.

    PYTHONPATH=src python examples/train_lm.py --preset smoke
    PYTHONPATH=src python examples/train_lm.py --preset full      # ~100M
    PYTHONPATH=src python examples/train_lm.py --preset smoke \
        --grad-compression 10                                     # E8M10 DP

The synthetic data is an order-1 Markov language (repro/data): uniform
entropy is ln(vocab); a model that learns the table approaches the
mixture floor, so the loss curve is a real learning signal, asserted at
the end.
"""
import argparse
import math
import shutil

import jax

from repro import configs
from repro.models.config import ModelConfig
from repro.optim import OptConfig
from repro.train import Trainer, TrainerConfig

PRESETS = {
    # ~1.6M params, < 2 min on 1 CPU
    "smoke": dict(
        model=dict(name="lm-smoke", family="dense", n_layers=2, d_model=128,
                   n_heads=4, n_kv_heads=2, d_ff=256, vocab=512,
                   dtype="float32"),
        steps=30, seq_len=128, global_batch=4, ckpt_every=15,
    ),
    # ~100M params — the assignment's end-to-end driver size
    "full": dict(
        model=dict(name="lm-100m", family="dense", n_layers=12, d_model=512,
                   n_heads=8, n_kv_heads=4, d_ff=2560, vocab=32_768,
                   dtype="float32"),
        steps=200, seq_len=512, global_batch=8, ckpt_every=50,
    ),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="smoke", choices=list(PRESETS))
    ap.add_argument("--steps", type=int, default=None)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    ap.add_argument("--resume", action="store_true",
                    help="keep existing checkpoints (restart test)")
    ap.add_argument("--grad-compression", type=int, default=None,
                    help="E8M<bits> gradient compression on the DP axis")
    args = ap.parse_args()

    p = PRESETS[args.preset]
    cfg = ModelConfig(**p["model"])
    steps = args.steps or p["steps"]
    if not args.resume:
        shutil.rmtree(args.ckpt_dir, ignore_errors=True)

    print(f"model: {cfg.name}  params ~{cfg.param_count() / 1e6:.1f}M  "
          f"steps {steps}")
    tcfg = TrainerConfig(
        steps=steps, ckpt_dir=args.ckpt_dir, ckpt_every=p["ckpt_every"],
        log_every=max(steps // 20, 1), seq_len=p["seq_len"],
        global_batch=p["global_batch"],
        grad_compression=args.grad_compression)
    opt = OptConfig(lr_peak=3e-3, warmup=max(steps // 10, 1),
                    total_steps=steps)
    trainer = Trainer(cfg, opt, tcfg)
    trainer.run()

    losses = [h["loss"] for h in trainer.history]
    uniform = math.log(cfg.vocab)
    print(f"\nloss: first {losses[0]:.3f} -> last {losses[-1]:.3f} "
          f"(uniform entropy {uniform:.3f})")
    assert losses[-1] < losses[0] - 0.2, "no learning signal!"
    print("learning-signal assertion passed; checkpoints:",
          trainer.ckpt.steps())


if __name__ == "__main__":
    main()
