"""The paper's solver scenario (§5.2, §6) with adaptive precision.

The codec is no longer hard-coded: ``repro.precision.select`` picks the
``(codec, D)`` split for an error budget, and ``solvers.cg.adaptive_pcg``
runs the mixed-precision PCG recipe end-to-end — low-precision inner
solves, residual-stagnation detection, codec-tier promotion mid-solve.
Also prints the Fig. 12-style IO-CG / F3R convergence comparison.

    PYTHONPATH=src python examples/mixed_precision_solver.py [--nx 10]
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

jax.config.update("jax_enable_x64", True)

from repro.core import testmats                             # noqa: E402
from repro.solvers import cg, f3r, iocg                     # noqa: E402
from repro.solvers.operators import OperatorSet, sym_scale  # noqa: E402


def true_relres(a, x, b):
    b = np.asarray(b, np.float64)
    return float(np.linalg.norm(b - a @ np.asarray(x, np.float64))
                 / np.linalg.norm(b))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--nx", type=int, default=10)
    ap.add_argument("--budget", type=float, default=1e-3,
                    help="SpMV error budget handed to precision.select")
    args = ap.parse_args()

    a0 = testmats.hpcg(args.nx, args.nx, args.nx)
    a, _ = sym_scale(a0)
    ops = OperatorSet(a, C=32, sigma=256)
    n = a.shape[0]
    rng = np.random.default_rng(0)
    b = jnp.asarray(rng.random(n))              # paper: U[0,1) rhs
    print(f"HPCG {args.nx}^3: n={n}, nnz={a.nnz}\n")

    print(f"--- adaptive PCG (precision.select, budget={args.budget:g}) ---")
    plan = ops.precision_plan(args.budget)
    sel = next((c for c in plan.rationale["candidates"]
                if c["decision"].startswith("selected")), None)
    if sel is None:
        print(f"selected {plan.primary.label}: no packed codec fits the "
              f"budget ({plan.rationale.get('fallback', 'fp32 fallback')})")
    else:
        print(f"selected {plan.primary.label}:"
              f" probe_err={sel['probe_err']:.2e}"
              f" model_err={sel['model_err']:.2e}"
              f" bytes/nnz={sel['bytes_per_nnz']:.2f}")
    diag = ops.diag()
    dinv = jnp.asarray(np.where(diag == 0, 1.0, 1.0 / diag))
    M = lambda r: r * dinv                                   # noqa: E731

    x, info = cg.pcg(ops.matvec("fp64"), b, M=M, tol=1e-8, maxiter=1000,
                     dtype=jnp.float64)
    print(f"{'PCG (FP64 baseline)':28s} iters={int(info.iters):4d} "
          f"true relres={true_relres(a, x, b):.2e}")

    tiers, labels, sub32, hi = ops.adaptive_tiers(args.budget)
    x, ainfo = cg.adaptive_pcg(tiers, b, M=M, matvec_hi=hi, tol=1e-8,
                               maxiter=60, m_in=16, dtype=jnp.float64)
    counts = np.asarray(ainfo.tier_matvecs)
    total = counts.sum() + int(ainfo.hi_matvecs)
    frac = counts[np.asarray(sub32)].sum() / max(total, 1)
    print(f"{'adaptive PCG (' + labels[0] + ')':28s} "
          f"outer={int(ainfo.iters):4d} "
          f"true relres={true_relres(a, x, b):.2e} "
          f"promotions={int(ainfo.promotions)} "
          f"sub-32-bit matvecs={frac:.0%}")

    print("\n--- IO-CG (outer FP64 FCG + m_in=20 inner PCG) ---")
    x, info = iocg.pcg_reference(ops, b)
    print(f"{'PCG (FP64 baseline)':28s} iters={int(info.iters):4d} "
          f"true relres={true_relres(a, x, b):.2e}")
    for v in ("fp64", "fp32", "fp16", "e8m8"):
        cfg = iocg.variant(v, m_in=20)
        x, info = iocg.solve(ops, b, cfg)
        label = {"e8m8": "E8M14 (PackSELL)"}.get(v, v.upper())
        print(f"{'IO-CG ' + label:28s} outer={int(info.iters):4d} "
              f"true relres={true_relres(a, x, b):.2e}")

    print("\n--- F3R (nested FGMRES x3 + Richardson) ---")
    for v in ("fp64", "fp16", "packsell"):
        cfg = f3r.presets(v)
        x, info = f3r.solve(ops, b, cfg)
        label = {"fp64": "FP64-F3R", "fp16": "FP16-F3R (SELL)",
                 "packsell": "PackSELL-F3R"}[v]
        print(f"{label:28s} cycles={int(info.iters):4d} "
              f"true relres={true_relres(a, x, b):.2e}")
    print("\nFP16-F3R and PackSELL-F3R must show identical cycle counts "
          "(the paper's identical-convergence claim).")


if __name__ == "__main__":
    main()
