"""The paper's solver scenario (§5.2): F3R and IO-CG with PackSELL SpMV.

Prints a Fig. 12-style convergence comparison: FP64 PCG baseline vs IO-CG
variants (FP32 / FP16 / E8MY inner SpMV) and the three F3R builds.

    PYTHONPATH=src python examples/mixed_precision_solver.py [--nx 10]
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

jax.config.update("jax_enable_x64", True)

from repro.core import testmats                             # noqa: E402
from repro.solvers import f3r, iocg                         # noqa: E402
from repro.solvers.operators import OperatorSet, sym_scale  # noqa: E402


def true_relres(a, x, b):
    b = np.asarray(b, np.float64)
    return float(np.linalg.norm(b - a @ np.asarray(x, np.float64))
                 / np.linalg.norm(b))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--nx", type=int, default=10)
    args = ap.parse_args()

    a0 = testmats.hpcg(args.nx, args.nx, args.nx)
    a, _ = sym_scale(a0)
    ops = OperatorSet(a, C=32, sigma=256)
    n = a.shape[0]
    rng = np.random.default_rng(0)
    b = jnp.asarray(rng.random(n))              # paper: U[0,1) rhs
    print(f"HPCG {args.nx}^3: n={n}, nnz={a.nnz}\n")

    print("--- IO-CG (outer FP64 FCG + m_in=20 inner PCG) ---")
    x, info = iocg.pcg_reference(ops, b)
    print(f"{'PCG (FP64 baseline)':28s} iters={int(info.iters):4d} "
          f"true relres={true_relres(a, x, b):.2e}")
    for v in ("fp64", "fp32", "fp16", "e8m8"):
        cfg = iocg.variant(v, m_in=20)
        x, info = iocg.solve(ops, b, cfg)
        label = {"e8m8": "E8M14 (PackSELL)"}.get(v, v.upper())
        print(f"{'IO-CG ' + label:28s} outer={int(info.iters):4d} "
              f"true relres={true_relres(a, x, b):.2e}")

    print("\n--- F3R (nested FGMRES x3 + Richardson) ---")
    for v in ("fp64", "fp16", "packsell"):
        cfg = f3r.presets(v)
        x, info = f3r.solve(ops, b, cfg)
        label = {"fp64": "FP64-F3R", "fp16": "FP16-F3R (SELL)",
                 "packsell": "PackSELL-F3R"}[v]
        print(f"{label:28s} cycles={int(info.iters):4d} "
              f"true relres={true_relres(a, x, b):.2e}")
    print("\nFP16-F3R and PackSELL-F3R must show identical cycle counts "
          "(the paper's identical-convergence claim).")


if __name__ == "__main__":
    main()
