"""Adaptive precision: accuracy-vs-throughput frontier + adaptive PCG trace.

Two figures (the paper's accuracy/performance trade-off analogues,
DESIGN.md §8):

1. **Frontier** — for each suite matrix, every candidate codec's measured
   probe error against its SpMV throughput and bytes/nnz, with the
   selector's pick at a few budgets marked. This is the curve
   ``precision.select`` walks.
2. **Adaptive PCG trace** — outer-residual trajectory of
   ``solvers.cg.adaptive_pcg`` (tier per step, promotions) vs the
   full-FP32 PCG baseline on the SPD classes: iterations, wall time, and
   the fraction of matvecs served by a sub-32-bit codec.

Writes ``BENCH_precision.json`` at the repo root (perf trajectory file).
"""
from __future__ import annotations

import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import packsell as pk
from repro.core import testmats
from repro.kernels import plan as kplan
from repro.precision import analyze, select_codec
from repro.precision.select import DEFAULT_CANDIDATES
from repro.solvers import cg
from repro.solvers.operators import OperatorSet, row_scale, sym_scale

from . import common

_JSON_PATH = os.environ.get(
    "REPRO_BENCH_PRECISION_JSON",
    os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                 "BENCH_precision.json"))

BUDGETS = (1e-2, 1e-4, 1e-6)


def _spd_suite(scale: str) -> dict:
    if scale == "tiny":
        return {"banded": testmats.random_banded(512, 24, 6, seed=1),
                "powerlaw": testmats.powerlaw(512, mean_deg=5, spd=True,
                                              seed=2)}
    n = 4000 if scale == "small" else 20_000
    return {"banded": testmats.random_banded(n, 24, 6, seed=1),
            "powerlaw": testmats.powerlaw(n, mean_deg=5, spd=True, seed=2)}


def _frontier(name: str, a0) -> list:
    """Probe error vs throughput for every candidate on one matrix."""
    a, _ = row_scale(a0)
    a = a.tocsr()
    a.sort_indices()
    rng = np.random.default_rng(11)
    x = jnp.asarray(rng.standard_normal(a.shape[1]).astype(np.float32))
    rows = []
    for codec, D in DEFAULT_CANDIDATES:
        mat = pk.from_csr(a, C=32, sigma=256, D=D, codec=codec)
        # dispatch through the plan engine: the matrix rides as a jit
        # ARGUMENT (a closure constant would be XLA-constant-folded —
        # minutes of compile per candidate on the wide matrices)
        plan = kplan.get_plan(mat)
        fn = lambda x, mm=mat, p=plan: p.spmv(mm, x)      # noqa: E731
        t = common.time_fn(fn, x)
        perr = analyze.probe_error(a, codec, D, n_probes=2, seed=0)
        st = mat.memory_stats()
        row = dict(codec=codec, D=D, t_us=t * 1e6,
                   probe_err=perr,
                   bytes_per_nnz=st["packsell_bytes"] / max(a.nnz, 1),
                   dummy_frac=mat.n_dummy / max(a.nnz, 1))
        rows.append(row)
        common.emit("precision_frontier", f"{name}_{codec}{D}", **row)
    # the selector's picks at each budget
    for budget in BUDGETS:
        plan = select_codec(a, budget, n_probes=2)
        c = plan.primary
        common.emit("precision_select", f"{name}_b{budget:g}",
                    budget=budget, codec=c.codec, D=c.D)
        rows.append(dict(selected_at_budget=budget, codec=c.codec, D=c.D))
    return rows


def _adaptive_trace(name: str, a0, budget: float = 1e-3) -> dict:
    """adaptive_pcg iteration/time trace vs full-FP32 PCG."""
    a, _ = sym_scale(a0)
    ops = OperatorSet(a, C=32, sigma=256)
    rng = np.random.default_rng(0)
    b = jnp.asarray(rng.standard_normal(a.shape[0]))
    diag = ops.diag()
    dinv = jnp.asarray(np.where(diag == 0, 1.0, 1.0 / diag))
    M = lambda r: r * dinv                                   # noqa: E731

    def timed(fn):
        fn()                          # compile
        t0 = time.perf_counter()
        out = fn()
        jax.block_until_ready(out[0])
        return out, time.perf_counter() - t0

    mv32 = ops.matvec("fp32")
    (x32, i32), t_fp32 = timed(lambda: cg.pcg(
        mv32, b, M=M, tol=1e-8, maxiter=2000))
    tiers, labels, sub32, hi = ops.adaptive_tiers(budget, n_probes=2)
    (xa, ia), t_ad = timed(lambda: cg.adaptive_pcg(
        tiers, b, M=M, matvec_hi=hi, tol=1e-8, maxiter=60, m_in=16))

    btrue = np.asarray(b, np.float64)
    true32 = float(np.linalg.norm(btrue - a @ np.asarray(x32, np.float64))
                   / np.linalg.norm(btrue))
    truead = float(np.linalg.norm(btrue - a @ np.asarray(xa, np.float64))
                   / np.linalg.norm(btrue))
    counts = np.asarray(ia.tier_matvecs)
    total_mv = int(counts.sum() + int(ia.hi_matvecs))
    frac = float(counts[np.asarray(sub32)].sum() / max(total_mv, 1))
    k = int(ia.iters)
    trace = dict(
        ladder=labels, budget=budget,
        fp32_pcg=dict(iters=int(i32.iters), true_relres=true32,
                      t_s=t_fp32, matvecs=int(i32.iters) + 1),
        adaptive=dict(outer=k, true_relres=truead, t_s=t_ad,
                      relres_history=[float(v) for v in
                                      np.asarray(ia.history)[:k + 1]],
                      tier_history=[int(v) for v in
                                    np.asarray(ia.tier_history)[:k]],
                      promotions=int(ia.promotions),
                      tier_matvecs=[int(c) for c in counts],
                      hi_matvecs=int(ia.hi_matvecs),
                      sub32_matvec_frac=frac),
    )
    common.emit("precision_adaptive", name,
                fp32_iters=int(i32.iters), fp32_true=true32,
                adaptive_outer=k, adaptive_true=truead,
                promotions=int(ia.promotions), sub32_frac=frac,
                t_fp32_s=t_fp32, t_adaptive_s=t_ad)
    return trace


def run(scale: str | None = None) -> None:
    scale = scale or common.SCALE
    frontier = {}
    for name, a0 in testmats.suite("tiny" if scale == "tiny"
                                   else "small").items():
        frontier[name] = _frontier(name, a0)

    traces = {}
    for name, a0 in _spd_suite(scale).items():
        traces[name] = _adaptive_trace(name, a0)

    payload = dict(
        scale=scale, backend=jax.default_backend(),
        note=("frontier: probe error vs SpMV throughput per codec, with "
              "select_codec picks; adaptive: adaptive_pcg trace vs "
              "full-FP32 PCG (both to 1e-8)"),
        frontier=frontier, adaptive=traces,
    )
    common.save_bench_json(_JSON_PATH, payload)
