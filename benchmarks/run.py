"""Benchmark harness entry point — one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only spmv,e8my] [--scale small]

Output: CSV lines ``bench,case,k=v,...`` plus artifacts/bench_results.json.
Scales: tiny (CI), small (default), medium.
"""
from __future__ import annotations

import argparse
import sys
import time

from . import common

MODULES = ("spmv", "memory", "e8my", "f3r", "iocg", "kernels", "roofline",
           "distributed", "precision", "composite", "robust", "serving")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma list from: " + ",".join(MODULES))
    ap.add_argument("--scale", default=common.SCALE)
    ap.add_argument("--out", default="artifacts/bench_results.json")
    args = ap.parse_args()
    only = args.only.split(",") if args.only else list(MODULES)

    t0 = time.time()
    failures = []
    for name in only:
        mod = __import__(f"benchmarks.bench_{name}", fromlist=["run"])
        print(f"### bench_{name} (scale={args.scale})", flush=True)
        t1 = time.time()
        try:
            mod.run(args.scale)
        except Exception as e:  # noqa: BLE001 — report, continue the suite
            failures.append((name, repr(e)))
            print(f"[FAIL] bench_{name}: {e!r}", flush=True)
        print(f"### bench_{name} done in {time.time() - t1:.1f}s", flush=True)
    common.save_rows(args.out)
    print(f"[benchmarks] total {time.time() - t0:.1f}s, "
          f"{len(failures)} failures")
    if failures:
        for name, err in failures:
            print(f"  FAILED {name}: {err}")
        sys.exit(1)


if __name__ == "__main__":
    main()
