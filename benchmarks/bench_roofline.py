"""§Roofline table: read the dry-run sweep artifact and print per-cell
roofline terms (compute / memory / collective, dominant, fractions).

The dry-run itself must run in its own process (512 placeholder devices);
this bench only *reads* ``artifacts/dryrun_all.json``. Regenerate with:

    PYTHONPATH=src python -m repro.launch.dryrun --all --both-meshes \
        --out artifacts/dryrun_all.json
"""
from __future__ import annotations

import json
import os

from . import common

_CANDIDATES = ("artifacts/dryrun_optimized.json", "artifacts/dryrun_all.json")
ARTIFACT = os.environ.get("REPRO_DRYRUN_JSON", "")


def _pick() -> str | None:
    if ARTIFACT:
        return ARTIFACT if os.path.exists(ARTIFACT) else None
    for c in _CANDIDATES:
        if os.path.exists(c):
            return c
    return None


def run(scale: str | None = None) -> None:
    path = _pick()
    if path is None:
        common.emit("roofline", "missing_artifact", path=str(_CANDIDATES))
        return
    common.emit("roofline", "source", path=path)
    with open(path) as f:
        cells = json.load(f)
    for rec in cells:
        tag = f"{rec['arch']}__{rec['shape']}__{rec['mesh']}"
        if rec["status"] != "ok":
            common.emit("roofline", tag, status=rec["status"])
            continue
        r = rec["roofline"]
        common.emit(
            "roofline", tag,
            t_compute_s=r["t_compute_s"],
            t_memory_s=r["t_memory_s"],
            t_collective_s=r["t_collective_s"],
            dominant=r["dominant"],
            roofline_fraction=r["roofline_fraction"],
            useful_flops_ratio=r["useful_flops_ratio"],
        )
