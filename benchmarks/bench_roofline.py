"""Roofline scoreboard: achieved bandwidth vs backend peak (DESIGN.md §12.4).

For every tiny-suite matrix class × codec this measures the steady-state
plan-dispatch SpMV time (interleaved across codecs per class so container
noise cancels out of the ratios, :func:`benchmarks.common.time_fns`) and
scores it against three byte models:

* **stream model** — the plan's own hot-path accounting: the fused word
  stream (or the bucketed packs) + the decode cache + x read once + y
  written once (``SpMVPlan.decode_cache_stats``).  Measured GB/s =
  stream bytes / t; this is THE figure the achieved-vs-peak fraction
  uses, matching BENCH_spmv.json's bandwidth column.
* **format model** — ``composite_memory_stats`` via
  ``plan.as_composite(mat).memory_stats()``: resident format bytes +
  vectors.  Equals the stream model when nothing is repacked; diverges
  by run-padding + checkpoint overhead on the fused path.
* **HLO cross-check** — ``launch.hlo_cost.aggregate`` over the COMPILED
  dispatch HLO: what XLA actually moves at fusion boundaries, including
  decode intermediates.  Always >= the stream model (decode materializes
  unpacked values); recorded as ``hlo_vs_model_ratio`` and gated by
  ``HLO_TOLERANCE`` — a cell is flagged when the compiled traffic is
  more than that factor off the model (fusion regression or a broken
  byte model).

The peak-bandwidth denominator comes from
:func:`repro.launch.roofline.peak_bandwidth`: a hardware constant on
TPU/GPU, a measured STREAM-triad probe on CPU (source string recorded).

The run executes with the flight recorder enabled and embeds
``repro.observe.report()`` in the payload, so the dispatch counters /
bytes-per-nnz gauges land next to the timings they describe.

Writes ``BENCH_roofline.json`` at the repo root.  The legacy dry-run
roofline-term dump (launch-planner cells) is kept as an extra section
when an ``artifacts/dryrun*.json`` sweep artifact exists.
"""
from __future__ import annotations

import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro import observe
from repro.core import packsell as pk
from repro.core import testmats
from repro.kernels import plan as kplan
from repro.launch import hlo_cost
from repro.launch import roofline as rl

from . import common

_JSON_PATH = os.environ.get(
    "REPRO_BENCH_ROOFLINE_JSON",
    os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                 "BENCH_roofline.json"))

#: codec columns of the scoreboard: the fp16 embed (paper default) and a
#: sub-16-bit pack — the two ends of the bytes/nnz range the tiny suite
#: exercises without a per-matrix selector run.
CODECS = (("fp16", 15), ("e8m", 8))

#: flag a cell when compiled HLO bytes exceed the stream model by more
#: than this factor (the decode epilogue materializes fp32 intermediates,
#: so ~2-4x is the healthy fused-path range on CPU; >8x means XLA stopped
#: fusing the decode or the byte model broke)
HLO_TOLERANCE = float(os.environ.get("REPRO_ROOFLINE_HLO_TOL", "8.0"))


def _hlo_text(plan, mat, x) -> str:
    """Compiled optimized-HLO text of one plan dispatch — feeds both the
    byte cross-check and (``--profile``) the op->span attribution join."""
    fn = jax.jit(plan._execute, static_argnums=(3,))
    return fn.lower(plan._exec_mat(mat), plan._device_operands(), x,
                    False).compile().as_text()


def _hlo_bytes(txt: str) -> float:
    """Bytes moved by one compiled plan dispatch, per the HLO cost model
    (static analysis of the optimized module — no execution)."""
    return float(hlo_cost.aggregate(txt)["bytes"])


def _span_profile(plan, mat, x, hlo_txt: str) -> dict:
    """Per-cell device-time span breakdown (``--profile``): run the plan
    dispatch under ``observe.profile.profile_dispatch`` with the SAME
    compiled-HLO text the byte cross-check lowered, so trace events join
    against exactly the executable being measured."""
    from repro.observe import profile as obs_profile

    sp = obs_profile.profile_dispatch(
        lambda v: plan.spmv(mat, v), x, hlo_texts=(hlo_txt,), repeats=10)
    d = sp.to_dict()
    # trim event payloads the scoreboard does not need
    d["spans"] = {k: {kk: vv for kk, vv in v.items()}
                  for k, v in d["spans"].items()
                  if v["device_s"] > 0 or v["host_s"] > 0 or v["ops"]}
    return d


def _cells(name: str, a, peak: dict, profile: bool = False) -> list[dict]:
    """One scoreboard row per codec for matrix class ``name`` — both
    codecs timed interleaved so the fp16-vs-packed ratio is paired."""
    a = a.tocsr()
    a.sort_indices()
    rng = np.random.default_rng(5)
    x = jnp.asarray(rng.standard_normal(a.shape[1]).astype(np.float32))

    mats, plans, plans_pl = {}, {}, {}
    for codec, D in CODECS:
        key = f"{codec}{D}"
        mats[key] = pk.from_csr(a, C=32, sigma=256, D=D, codec=codec)
        plans[key] = kplan.get_plan(mats[key])
        # the pallas-fused variant of the same cell (kernel over the same
        # stream; interpret mode off-TPU). Demotes to jnp when the stream
        # is infeasible — the variant column records which one ran.
        plans_pl[key] = kplan.build_plan(mats[key], force="fused")

    ts = common.time_fns(
        {k: (lambda v, mm=mats[k], p=plans[k]: p.spmv(mm, v))
         for k in mats},
        {k: (x,) for k in mats}, rounds=15, samples=True)
    # paired jnp-fused vs pallas-fused timings, few rounds (interpret
    # mode runs the kernel body in Python off-TPU)
    pl_keys = [k for k in mats if plans_pl[k].variant == "fused"]
    ts_pl = common.time_fns(
        {k: (lambda v, mm=mats[k], p=plans_pl[k]: p.spmv(mm, v))
         for k in pl_keys},
        {k: (x,) for k in pl_keys},
        rounds=3, samples=True) if pl_keys else {}

    rows = []
    for codec, D in CODECS:
        key = f"{codec}{D}"
        mat, plan = mats[key], plans[key]
        t = float(np.median(ts[key]))
        nnz = max(int(mat.nnz), 1)

        dcs = plan.decode_cache_stats()
        vec_bytes = 4 * (mat.n + mat.m)
        stream_bytes = (dcs["fused_stream_bytes"] or 4 * plan.total_words) \
            + dcs["decode_cache_bytes"] + vec_bytes
        fmt = plan.as_composite(mat).memory_stats()
        model_bytes = fmt["composite_bytes"] + vec_bytes
        hlo_txt = _hlo_text(plan, mat, x)
        hlo = _hlo_bytes(hlo_txt)

        gbs = stream_bytes / t / 1e9
        frac = gbs * 1e9 / peak["bw_bytes_per_s"]
        ratio = hlo / max(stream_bytes, 1)
        row = dict(
            klass=name, codec=codec, D=D, n=mat.n, nnz=int(mat.nnz),
            variant=plan.variant, cache_mode=plan.cache_mode,
            t_spmv_s=t,
            stream_bytes=int(stream_bytes),
            bytes_per_nnz=(stream_bytes - vec_bytes) / nnz,
            format_bytes=int(model_bytes),
            format_bytes_per_nnz=fmt["bytes_per_nnz"],
            hlo_bytes=hlo,
            hlo_vs_model_ratio=ratio,
            hlo_within_tolerance=bool(ratio <= HLO_TOLERANCE),
            measured_gbs=gbs,
            peak_gbs=peak["bw_bytes_per_s"] / 1e9,
            achieved_frac_of_peak=frac,
            variant_pallas=plans_pl[key].variant,
            t_spmv_pallas_s=(float(np.median(ts_pl[key]))
                             if key in ts_pl else None),
            pallas_vs_jnp=((t / float(np.median(ts_pl[key])))
                           if key in ts_pl else None),
        )
        if profile:
            prof = _span_profile(plan, mat, x, hlo_txt)
            row["span_profile"] = prof
            tag = ("profiler_unavailable" if prof["profiler_unavailable"]
                   else f"accounted={prof['accounted_frac_of_wall']:.2f} "
                        f"span_dev={prof['coverage_of_wall']:.2f} "
                        f"host={prof['host_overhead_s'] * 1e6:.1f}us")
            print(f"  profile {name}/{key}: {tag}")
        rows.append(row)
        common.emit("roofline_spmv", f"{name}_{key}",
                    **{k: v for k, v in row.items()
                       if k not in ("klass", "span_profile")})
    return rows


def _legacy_dryrun_cells() -> list[dict]:
    """The pre-§12 behaviour of this module: per launch-planner cell
    roofline terms read from a dry-run sweep artifact, when one exists."""
    path = os.environ.get("REPRO_DRYRUN_JSON", "")
    for c in ((path,) if path else
              ("artifacts/dryrun_optimized.json", "artifacts/dryrun_all.json")):
        if c and os.path.exists(c):
            path = c
            break
    else:
        return []
    with open(path) as f:
        cells = json.load(f)
    out = []
    for rec in cells:
        tag = f"{rec['arch']}__{rec['shape']}__{rec['mesh']}"
        if rec["status"] != "ok":
            out.append(dict(cell=tag, status=rec["status"]))
            continue
        r = rec["roofline"]
        out.append(dict(
            cell=tag, status="ok", dominant=r["dominant"],
            t_compute_s=r["t_compute_s"], t_memory_s=r["t_memory_s"],
            t_collective_s=r["t_collective_s"],
            roofline_fraction=r["roofline_fraction"],
            useful_flops_ratio=r["useful_flops_ratio"]))
    return out


def run(scale: str | None = None, profile: bool | None = None) -> None:
    scale = scale or common.SCALE
    if profile is None:
        profile = os.environ.get("REPRO_BENCH_PROFILE", "0") not in (
            "0", "", "false")
    prev = observe.enable(True)          # the run records itself
    try:
        peak = rl.peak_bandwidth()
        common.emit("roofline_peak", peak["backend"],
                    peak_gbs=peak["bw_bytes_per_s"] / 1e9,
                    source=peak["source"])
        cells = []
        for name, a in testmats.suite("tiny").items():
            cells.extend(_cells(name, a, peak, profile=profile))

        bad = [f"{c['klass']}/{c['codec']}{c['D']}" for c in cells
               if not c["hlo_within_tolerance"]]
        payload = dict(
            scale=scale, backend=jax.default_backend(),
            profiled=bool(profile),
            peak_bandwidth=peak,
            hlo_tolerance=HLO_TOLERANCE,
            hlo_cells_out_of_tolerance=bad,
            note=("stream model = fused word stream + decode cache + x + y "
                  "(the BENCH_spmv bandwidth convention); format model = "
                  "composite_memory_stats resident bytes + vectors; "
                  "hlo_bytes = static cost of the compiled dispatch "
                  "(includes decode intermediates, so ratio > 1 is "
                  "expected; > hlo_tolerance is flagged); "
                  "achieved_frac_of_peak divides the stream-model GB/s by "
                  "peak_bandwidth (hardware constant on TPU/GPU, STREAM "
                  "probe on CPU)"),
            cells=cells,
            observe_report=observe.report(),
            legacy_dryrun=_legacy_dryrun_cells(),
        )
        common.save_bench_json(_JSON_PATH, payload)
    finally:
        observe.enable(prev)


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", default=None)
    ap.add_argument("--profile", action="store_true",
                    help="attach a per-cell device-time span breakdown "
                         "(observe.profile) to every scoreboard cell")
    ns = ap.parse_args()
    run(ns.scale, profile=ns.profile or None)
