"""Serving front-end benchmark: open-loop Poisson traffic with an
overload phase and a fault slice (DESIGN.md §15.7).

An open-loop arrival process (arrivals are scheduled ahead of time and
never wait for the service — the honest way to measure a saturated
queue) drives :class:`~repro.serving.frontend.ServingFrontend` on the
REAL monotonic clock through three phases:

* ``normal``   — 0.8x measured capacity: the no-stress baseline.
* ``overload`` — 2.0x measured capacity: sheds, demotions, and the
  p99 under sustained saturation.
* ``fault``    — 2.0x capacity PLUS a fused/pack word-flip campaign:
  what the guard + breaker + rebuild machinery costs when operands rot
  mid-service, and the delivered-accuracy ledger (``out_of_budget``
  must be 0 — corrupted answers are retried or rerouted, not shipped).

Capacity is measured, not assumed: the slot service time is timed on
warmed plans, so arrival rates track the host the bench runs on.

Per phase -> BENCH_serving.json (schema-versioned, trajectory-
ingestable; serving metrics stay ADVISORY — they are intentionally not
in ``observe.trajectory.GATED_METRICS``): sustained QPS, p50/p99
latency, shed rate, deadline-miss rate, and the per-tier matvec
fractions showing the precision ladder absorbing the overload.
"""
from __future__ import annotations

import logging
import os
import time

import numpy as np

from repro.core import testmats
from repro.robust import inject as inj
from repro.serving import frontend as fe
from repro.serving import policy as pol

from . import common

_JSON_PATH = os.environ.get(
    "REPRO_BENCH_SERVING_JSON",
    os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                 "BENCH_serving.json"))

SLOTS = 4
MAX_QUEUE = 64

#: per-scale (phase seconds, fault injections)
_SCALES = {"tiny": (0.6, 10), "small": (2.0, 25), "medium": (5.0, 50)}


def _frontend() -> fe.ServingFrontend:
    cfg = fe.FrontendConfig(
        slots=SLOTS, background=False, C=32, sigma=64,
        admission=pol.AdmissionPolicy(max_queue=MAX_QUEUE,
                                      shed_watermark=0.9),
        fail_threshold=1, cooldown_s=0.005,
        backoff=pol.BackoffPolicy(base=0.002, max_attempts=3))
    return fe.ServingFrontend(cfg)


def _measure_capacity(f: fe.ServingFrontend, fp: str, xs: list) -> float:
    """Requests/second one slot pipeline sustains on warmed plans."""
    for rep in range(2):                       # warm every tier's trace
        for j in range(SLOTS):
            f.submit(fp, xs[j], klass="interactive")
        f.run_until_drained()
    t0 = time.perf_counter()
    rounds = 5
    for _ in range(rounds):
        for j in range(SLOTS):
            f.submit(fp, xs[j], klass="interactive")
        f.run_until_drained()
    dt = time.perf_counter() - t0
    return rounds * SLOTS / dt


def _inject_one(f: fe.ServingFrontend, fp: str, rng) -> bool:
    entry = f._entry(fp)
    kinds = list(entry.guards)
    if not kinds:
        return False
    kind = kinds[int(rng.integers(len(kinds)))]
    mat, plan, _ = entry.bind(kind)
    try:
        inj.flip_fused_word(mat, plan, seed=int(rng.integers(1 << 30)))
    except ValueError:                         # plan carries no fused
        inj.flip_pack_word(mat, plan, seed=int(rng.integers(1 << 30)))
    return True


def _phase(f: fe.ServingFrontend, fp: str, xs: list, *, rate: float,
           duration: float, rng, injections: int = 0) -> list:
    """Open-loop Poisson arrivals at ``rate`` for ``duration`` seconds;
    optional evenly-spread word-flip campaign.  Returns the phase's
    requests (drained)."""
    classes = ("interactive", "standard", "batch")
    t0 = time.perf_counter()
    next_arrival = t0 + float(rng.exponential(1.0 / rate))
    inject_at = [t0 + duration * (i + 1) / (injections + 1)
                 for i in range(injections)]
    reqs = []
    while True:
        now = time.perf_counter()
        if now >= t0 + duration:
            break
        while next_arrival <= now:
            reqs.append(f.submit(
                fp, xs[int(rng.integers(len(xs)))],
                klass=classes[int(rng.integers(3))]))
            next_arrival += float(rng.exponential(1.0 / rate))
        while inject_at and inject_at[0] <= now:
            inject_at.pop(0)
            _inject_one(f, fp, rng)
        f.step()
    f.run_until_drained(max_ticks=100_000)
    return reqs


def _summarize(name: str, reqs: list, duration: float, a_csr,
               budget_safety: float = 16.0) -> dict:
    oks = [r for r in reqs if r.status == "ok" and r.op == "spmv"]
    lat = np.sort([r.latency for r in oks]) if oks else np.array([0.0])
    n = max(len(reqs), 1)
    shed = sum(1 for r in reqs if r.status in ("shed", "rejected"))
    missed = sum(1 for r in reqs
                 if r.status == "deadline_miss" or r.missed_deadline)
    tiers: dict = {}
    for r in oks:
        tiers[r.tier_kind] = tiers.get(r.tier_kind, 0) + 1
    a64 = a_csr.astype(np.float64)
    anorm = float(np.max(np.abs(a_csr).sum(axis=1)))
    oob = 0
    for r in oks:
        kind = "fp32" if r.tier_kind == "fp32_fallback" else r.tier_kind
        x64 = np.asarray(r.x, np.float64)
        err = float(np.max(np.abs(np.asarray(r.y, np.float64) - a64 @ x64)))
        tol = pol.tier_error_budget(kind, safety=budget_safety)
        if err > tol * max(anorm * float(np.max(np.abs(x64))), 1e-300):
            oob += 1
    row = dict(
        requests=len(reqs), completed_ok=len(oks),
        qps=len(oks) / duration,
        p50_latency_s=float(lat[int(0.5 * (len(lat) - 1))]),
        p99_latency_s=float(lat[int(0.99 * (len(lat) - 1))]),
        shed_rate=shed / n, deadline_miss_rate=missed / n,
        out_of_budget=oob,
        **{f"frac_{k}": v / max(len(oks), 1) for k, v in sorted(
            tiers.items())})
    common.emit("serving", name, **row)
    return row


def run(scale: str | None = None) -> None:
    scale = scale or common.SCALE
    duration, injections = _SCALES.get(scale, _SCALES["small"])
    # per-request shed/reject warnings are the service's loud-rejection
    # contract, but at 2x-capacity open-loop rates the logging I/O alone
    # would throttle the system under test — counters carry the tally
    logging.getLogger("repro.serving.frontend").setLevel(logging.ERROR)
    a = testmats.suite("tiny")["stencil1d"]
    rng = np.random.default_rng(42)
    xs = [rng.standard_normal(a.shape[1]).astype(np.float32)
          for _ in range(8)]

    with _frontend() as f:
        fp = f.register(a, warm=False)
        # warm EVERY ladder tier (overload will demote into all of them)
        # plus the fp32 fallback, so phases measure serving, not jit
        f._entry(fp).warmup(list(pol.DEFAULT_LADDER), SLOTS)
        cap = _measure_capacity(f, fp, xs)
        common.emit("serving", "capacity", slots=SLOTS,
                    capacity_qps=cap)

        _summarize("normal",
                   _phase(f, fp, xs, rate=0.5 * cap, duration=duration,
                          rng=rng), duration, a)
        _summarize("overload",
                   _phase(f, fp, xs, rate=2.0 * cap, duration=duration,
                          rng=rng), duration, a)
        fault = _phase(f, fp, xs, rate=2.0 * cap, duration=duration,
                       rng=rng, injections=injections)
        row = _summarize("fault", fault, duration, a)
        common.emit("serving", "fault_campaign", injections=injections,
                    out_of_budget=row["out_of_budget"],
                    breaker_transitions=len(
                        f._entry(fp).breaker.transitions))

    rows = [r for r in common.rows() if r["bench"] == "serving"]
    common.save_bench_json(_JSON_PATH, rows)


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", default=None)
    run(ap.parse_args().scale)
