"""Shared benchmark utilities: timing, CSV emission, result collection.

Wall-clock on this CPU container is a *relative* instrument (DESIGN.md §2):
every figure reports PackSELL against the SELL/CSR baselines timed the same
way, mirroring how the paper reports speedups rather than absolute device
FLOPS. Roofline-based absolute analysis lives in EXPERIMENTS.md §Roofline.
"""
from __future__ import annotations

import json
import os
import time

import jax
import numpy as np

SCALE = os.environ.get("REPRO_BENCH_SCALE", "small")   # tiny|small|medium

#: BENCH_*.json metadata-header schema. Bump when header fields change
#: meaning — trajectory tooling compares runs only within a schema version.
BENCH_SCHEMA_VERSION = 1

_ROWS: list[dict] = []


def bench_meta(**extra) -> dict:
    """Schema-versioned metadata header stamped into every BENCH_*.json.
    Provenance fields (commit, toolchain, machine) come from
    ``observe.export.run_meta`` — the SAME header telemetry archives
    carry, so bench files and metric streams stay joinable in the
    trajectory store."""
    from repro.observe import export as _export

    return {
        "schema_version": BENCH_SCHEMA_VERSION,
        **_export.run_meta(scale=SCALE, **extra),
    }


def save_bench_json(path: str, payload) -> None:
    """Write a checked-in BENCH_*.json with the :func:`bench_meta` header.
    ``payload`` may be a dict (header merged in under ``meta``) or a bare
    row list (wrapped as ``{"meta": ..., "rows": [...]}``).

    Every writer gets the flight-recorder treatment for free: unless the
    payload already carries an ``observe_report`` section, the current
    ``observe.report()`` snapshot is embedded (counters land next to the
    timings they describe), and the full telemetry state is archived as a
    JSONL delta under ``artifacts/obs/`` (``REPRO_OBS_ARCHIVE_DIR``; set
    to empty to disable)."""
    if not isinstance(payload, dict):
        payload = {"rows": payload}
    meta = bench_meta()
    if "observe_report" not in payload:
        try:
            from repro import observe as _observe

            payload = {**payload, "observe_report": _observe.report()}
        except Exception:
            pass
    payload = {"meta": meta, **payload}
    d = os.path.dirname(path)
    if d:
        os.makedirs(d, exist_ok=True)
    with open(path, "w") as f:
        json.dump(payload, f, indent=1, default=float)
    print(f"[benchmarks] wrote {path}")
    _archive_telemetry(path, meta)


def _archive_telemetry(bench_path: str, meta: dict) -> None:
    """Append this run's metric state to ``artifacts/obs/<bench>.jsonl``
    (one meta header per file, then snapshot-deltas — JsonlSink
    semantics), so the raw counters behind every committed BENCH figure
    survive next to the repo's other artifacts."""
    root = os.environ.get(
        "REPRO_OBS_ARCHIVE_DIR",
        os.path.join(os.path.dirname(os.path.dirname(
            os.path.abspath(__file__))), "artifacts", "obs"))
    if not root:
        return
    try:
        from repro.observe import export as _export

        stem = os.path.splitext(os.path.basename(bench_path))[0]
        sink = _export.JsonlSink(
            os.path.join(root, f"{stem}.jsonl"),
            meta={**meta, "bench_file": os.path.basename(bench_path)})
        sink.flush()
    except Exception as e:            # archive must never fail the bench
        print(f"[benchmarks] telemetry archive skipped: {e!r}")


def time_fn(fn, *args, warmup: int = 2, repeats: int = 5) -> float:
    """Median seconds per call of a jit-compatible fn (blocks on result)."""
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def time_fns(fns: dict, args: dict, *, warmup: int = 2,
             rounds: int = 9, samples: bool = False) -> dict:
    """Interleaved timings: one call of every fn per round (order rotated
    per round), so contended / throttled containers perturb all candidates
    alike. Returns per-fn medians; ``samples=True`` returns the raw
    per-round lists instead, for PAIRED statistics — e.g.
    :func:`paired_speedup`, the comparison instrument behind
    fused-vs-cursor in BENCH_spmv.json."""
    keys = list(fns)
    ts = {k: [] for k in keys}
    for k in keys:
        for _ in range(warmup):
            jax.block_until_ready(fns[k](*args[k]))
    for r in range(rounds):
        order = keys[r % len(keys):] + keys[:r % len(keys)]
        for k in order:
            t0 = time.perf_counter()
            jax.block_until_ready(fns[k](*args[k]))
            ts[k].append(time.perf_counter() - t0)
    if samples:
        return ts
    return {k: float(np.median(v)) for k, v in ts.items()}


def paired_speedup(ts: dict, base: str, cand: str) -> float:
    """Median of per-round ``t_base / t_cand`` ratios from
    :func:`time_fns(..., samples=True)`. Pairing cancels the machine's
    between-round throughput drift that poisons unpaired medians on a
    shared container."""
    return float(np.median(np.asarray(ts[base]) / np.asarray(ts[cand])))


def emit(bench: str, case: str, **fields):
    row = {"bench": bench, "case": case, **fields}
    _ROWS.append(row)
    kv = ",".join(f"{k}={_fmt(v)}" for k, v in fields.items())
    print(f"{bench},{case},{kv}", flush=True)
    return row


def _fmt(v):
    if isinstance(v, float):
        return f"{v:.6g}"
    return str(v)


def rows() -> list[dict]:
    return _ROWS


def save_rows(path: str):
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    with open(path, "w") as f:
        json.dump(_ROWS, f, indent=1, default=float)
    print(f"[benchmarks] wrote {len(_ROWS)} rows -> {path}")


def backward_error(y, a_csr, x) -> float:
    """Paper eq. (5): ||y - Ax||_inf / (||A||_inf ||x||_inf)."""
    y = np.asarray(y, np.float64)
    x = np.asarray(x, np.float64)
    exact = a_csr.astype(np.float64) @ x
    num = np.max(np.abs(y - exact))
    anorm = np.max(np.abs(a_csr).sum(axis=1))
    xnorm = np.max(np.abs(x))
    return float(num / max(anorm * xnorm, 1e-300))
