"""Fig. 10 analogue: F3R solver — FP64 vs FP16-SELL vs PackSELL-FP16.

FP16-F3R and PackSELL-F3R must show identical convergence (the paper:
"Since FP16 values are directly embedded in PackSELL, FP16-F3R and
PackSELL-F3R exhibit identical convergence") — asserted here — so the
wall-clock difference isolates the format. Also reports the FP64 GMRES
reference of the paper's right plot.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.core import testmats
from repro.solvers import f3r, gmres, precond
from repro.solvers.operators import OperatorSet, sym_scale

from . import common


def _problems(scale: str) -> dict:
    if scale == "tiny":
        return {"hpcg_6": testmats.hpcg(6, 6, 6),
                "hpgmp_6": testmats.hpgmp(6, 6, 6)}
    if scale == "small":
        return {"hpcg_12": testmats.hpcg(12, 12, 12),
                "hpgmp_12": testmats.hpgmp(12, 12, 12),
                "stencil1d_40k": testmats.stencil_1d(40_000, 3)}
    return {"hpcg_24": testmats.hpcg(24, 24, 24),
            "hpgmp_24": testmats.hpgmp(24, 24, 24),
            "stencil1d_150k": testmats.stencil_1d(150_000, 3)}


def run(scale: str | None = None) -> None:
    scale = scale or common.SCALE
    for name, a0 in _problems(scale).items():
        a, _ = sym_scale(a0)
        ops = OperatorSet(a, C=32, sigma=256)
        rng = np.random.default_rng(3)
        b = jnp.asarray(rng.random(a.shape[0]))  # paper: U[0,1) rhs

        results = {}
        for variant in ("fp64", "fp16", "packsell"):
            cfg = f3r.presets(variant)
            t = common.time_fn(
                lambda: f3r.solve(ops, b, cfg), warmup=1, repeats=3)
            x, info = f3r.solve(ops, b, cfg)
            relres = float(np.linalg.norm(
                np.asarray(b, np.float64)
                - a.astype(np.float64) @ np.asarray(x, np.float64))
                / np.linalg.norm(np.asarray(b, np.float64)))
            results[variant] = dict(t=t, iters=int(info.iters),
                                    relres=relres)
            common.emit("f3r", f"{name}_{variant}", t_s=t,
                        outer_iters=int(info.iters), true_relres=relres)

        # paper's invariant: identical convergence for fp16 vs packsell
        same = results["fp16"]["iters"] == results["packsell"]["iters"]
        common.emit(
            "f3r_speedup", name,
            packsell_vs_fp16=results["fp16"]["t"] / results["packsell"]["t"],
            packsell_vs_fp64=results["fp64"]["t"] / results["packsell"]["t"],
            identical_convergence=same,
        )

        # FP64 GMRES reference (restarted 100, AINV preconditioner)
        A64 = ops.matvec("fp64")
        M = precond.neumann_ainv(ops.diag(), A64, k=2, dtype=jnp.float64)
        t = common.time_fn(
            lambda: gmres.fgmres(A64, b, M=M, m=100, tol=1e-9,
                                 max_cycles=200, dtype=jnp.float64),
            warmup=1, repeats=1)
        common.emit("f3r_gmres_ref", name, t_s=t,
                    speedup_packsell_vs_gmres=t / results["packsell"]["t"])
