"""Distributed mixed-precision PCG vs distributed fp32 PCG (DESIGN.md §9).

The composition the CompositePlan refactor unlocks: the SAME matrix solved
on 2–8 simulated devices by (a) ``cg.jacobi_pcg_dist`` over an
uncompressed fp32 member set and (b) ``cg.adaptive_pcg_dist`` over the
budget-selected codec tier ladder (sub-32-bit inner matvecs, fp64
true-residual outer steps, tier promotion on stagnation). Records solve
time, iteration counts (must not drift with the shard count), the
sub-32-bit matvec fraction, and the dist-mixed vs dist-fp32 speedup.

JAX fixes the device count at backend initialization, so ``run``
re-executes this module in a subprocess with
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` and folds the
child's rows back into the shared results (same recipe as
``bench_distributed``; DESIGN.md §2.5's relative-instrument caveat applies
doubly on simulated devices).

Writes ``BENCH_composite.json`` at the repo root.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

N_DEV = 8
SHARD_COUNTS = (2, 4, 8)
_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
_JSON_PATH = os.environ.get("REPRO_BENCH_COMPOSITE_JSON",
                            os.path.join(_ROOT, "BENCH_composite.json"))


def run(scale: str | None = None) -> None:
    """Parent entry point (benchmarks.run): spawn the forced-device-count
    child, then re-ingest its rows."""
    from . import common
    scale = scale or common.SCALE
    env = os.environ.copy()
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") +
                        f" --xla_force_host_platform_device_count={N_DEV}"
                        ).strip()
    proc = subprocess.run(
        [sys.executable, "-m", "benchmarks.bench_composite",
         "--scale", scale],
        env=env, cwd=_ROOT)
    if proc.returncode != 0:
        raise RuntimeError(f"bench_composite child failed "
                           f"(exit {proc.returncode})")
    with open(_JSON_PATH) as f:
        payload = json.load(f)
    common.rows().extend(payload["rows"])


def _suite(scale: str):
    from repro.core import testmats
    if scale == "tiny":
        return testmats.hpcg(6, 6, 6), (1e-8, 40, 8)
    if scale == "small":
        return testmats.hpcg(12, 12, 12), (1e-8, 60, 16)
    return testmats.hpcg(16, 16, 16), (1e-8, 60, 16)      # medium


def _child(scale: str) -> None:
    import jax
    jax.config.update("jax_enable_x64", True)
    import jax.numpy as jnp
    import numpy as np

    from repro.solvers import cg
    from repro.solvers import operators as op

    from . import common

    ndev = jax.device_count()
    a, (tol, maxiter, m_in) = _suite(scale)
    s, _ = op.sym_scale(a)
    n = s.shape[0]
    d = s.diagonal()
    budget = 1e-3
    rng = np.random.default_rng(0)
    b = jnp.asarray(rng.standard_normal(n))

    ops = op.OperatorSet(s, C=32, sigma=64)
    plan = ops.precision_plan(budget)
    for P in SHARD_COUNTS:
        if P > ndev:
            continue
        from repro.distributed import build_dist_plan
        dp32 = build_dist_plan(s, P, C=32, sigma=64,
                               classes=[("fp32", 0, None)])
        _, i32 = cg.jacobi_pcg_dist(dp32, d, b, tol=tol, maxiter=400,
                                    dtype=jnp.float64)
        t32 = common.time_fn(
            lambda dp=dp32: cg.jacobi_pcg_dist(
                dp, d, b, tol=tol, maxiter=400, dtype=jnp.float64)[0],
            warmup=1, repeats=3)

        ladder = ops.dist_adaptive_tiers(budget, n_shards=P)
        xm, im = cg.adaptive_pcg_dist(ladder, d, b, tol=tol,
                                      maxiter=maxiter, m_in=m_in,
                                      dtype=jnp.float64)
        tm = common.time_fn(
            lambda la=ladder: cg.adaptive_pcg_dist(
                la, d, b, tol=tol, maxiter=maxiter, m_in=m_in,
                dtype=jnp.float64)[0],
            warmup=1, repeats=3)
        mv = np.asarray(im.tier_matvecs)
        sub32_frac = float(mv[np.asarray(ladder.sub32)].sum()
                           / max(mv.sum(), 1))
        r = np.asarray(s @ np.asarray(xm, np.float64)) - np.asarray(
            b, np.float64)
        common.emit(
            "dist_mixed_pcg", f"hpcg_p{P}", shards=P, n=n,
            nnz=int(s.nnz), budget=budget,
            primary=plan.primary.label, tiers=len(ladder.labels),
            fp32_iters=int(i32.iters), fp32_t_s=t32,
            mixed_outer_iters=int(im.iters),
            mixed_promotions=int(im.promotions),
            mixed_sub32_frac=sub32_frac,
            mixed_true_relres=float(np.linalg.norm(r)
                                    / np.linalg.norm(np.asarray(b))),
            mixed_t_s=tm, speedup_mixed_vs_fp32=t32 / tm)

    payload = dict(
        scale=scale, backend=jax.default_backend(), devices=ndev,
        note=("dist-mixed adaptive_pcg_dist vs dist-fp32 jacobi_pcg_dist "
              "on simulated host devices sharing one CPU: wall times "
              "measure dispatch + word-stream-volume effects, not real "
              "interconnect bandwidth; iteration counts are the invariant "
              "to watch (must not drift with P)"),
        rows=common.rows(),
    )
    common.save_bench_json(_JSON_PATH, payload)


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", default=None)
    args = ap.parse_args()
    _child(args.scale or os.environ.get("REPRO_BENCH_SCALE", "small"))
