"""Benchmark package. FP64 must be real FP64 here (the paper's outer Krylov
layers and the eq. (6) 1e-9 criterion depend on it), so enable x64 before
any benchmark module builds jit functions."""
import jax

jax.config.update("jax_enable_x64", True)
