"""Pallas kernel validation sweep: PackSELL/SELL kernels (interpret mode)
against the pure-jnp oracle across matrix classes, codecs and block shapes.

Interpret-mode wall-clock is meaningless (the kernel body runs in Python),
so this bench reports *correctness* (max |Δ| vs oracle) plus the static
VMEM working-set per grid step implied by the BlockSpecs — the quantity a
real-TPU deployment must keep under ~16 MB/core.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.core import packsell as pk
from repro.core import testmats
from repro.kernels import ops

from . import common


def _vmem_bytes(mat: pk.PackSELLMatrix, sb: int, wb: int, full_x: bool,
                hw: int = 4096) -> int:
    C = mat.C
    pack_tile = 4 * sb * wb * C
    scratch = (4 + 4) * sb * C
    out_tile = 4 * sb * C
    x_bytes = 4 * (mat.m if full_x else 2 * hw)
    return pack_tile + scratch + out_tile + x_bytes


def run(scale: str | None = None) -> None:
    suite = testmats.suite("tiny")
    for name, a in suite.items():
        x = jnp.asarray(
            np.random.default_rng(1).standard_normal(a.shape[1])
            .astype(np.float32))
        for codec, D in (("fp16", 15), ("bf16", 15), ("e8m", 8)):
            mat = pk.from_csr(a, C=128, sigma=256, D=D, codec=codec,
                              bucket_strategy="uniform")
            oracle = pk.packsell_spmv_jnp(mat, x)
            y = ops.packsell_spmv(mat, x, force="full")
            err = float(jnp.max(jnp.abs(y - oracle)))
            wins = ops.band_plan(mat, sb=8, hw=4096)
            rec = dict(max_abs_err_full=err,
                       vmem_full_kb=_vmem_bytes(mat, 8, 32, True) / 1024)
            if wins is not None:
                yb = ops.packsell_spmv(mat, x, force="band")
                rec["max_abs_err_band"] = float(jnp.max(jnp.abs(yb - oracle)))
                rec["vmem_band_kb"] = _vmem_bytes(mat, 8, 32, False) / 1024
            common.emit("kernel_check", f"{name}_{codec}_D{D}", **rec)
