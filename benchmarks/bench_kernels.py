"""Pallas kernel validation sweep + SpMVPlan engine benchmarks.

Three sections:

* correctness — PackSELL/SELL kernels (interpret mode) against the pure-jnp
  oracle across matrix classes, codecs and block shapes. Interpret-mode
  wall-clock is meaningless (the kernel body runs in Python), so this
  reports max |Δ| vs oracle plus the static VMEM working-set per grid step
  implied by the BlockSpecs — the quantity a real-TPU deployment must keep
  under ~16 MB/core.
* autotune — :func:`autotune` sweeps (sb, wb) per bucket shape, times the
  bucket kernel, and records the winner into the matrix's cached SpMVPlan
  (``plan.retile``).
* dispatch — plan-cached single-dispatch ``packsell_spmv`` vs the seed
  per-call path (host band planning + eager per-bucket loop-decode +
  per-bucket σ-scatter on every call), steady-state, cold build excluded.
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import packsell as pk
from repro.core import testmats
from repro.kernels import ops
from repro.kernels import packsell_spmv as _pk
from repro.kernels import plan as kplan

from . import common


def _vmem_bytes(mat: pk.PackSELLMatrix, sb: int, wb: int, full_x: bool,
                hw: int = 4096) -> int:
    C = mat.C
    pack_tile = 4 * sb * wb * C
    scratch = (4 + 4) * sb * C
    out_tile = 4 * sb * C
    x_bytes = 4 * (mat.m if full_x else 2 * hw)
    return pack_tile + scratch + out_tile + x_bytes


# ---------------------------------------------------------------------------
# Autotune: per-bucket (sb, wb) sweep recorded into the plan
# ---------------------------------------------------------------------------


def autotune(mat: pk.PackSELLMatrix, x: jnp.ndarray, *,
             sbs=(2, 4, 8), wbs=(8, 16, 32), wrs=None, force: str = "full",
             hw: int = 4096, interpret: bool | None = None,
             repeats: int = 3, store=None, fingerprint: str | None = None,
             store_key: str | None = None):
    """Sweep (sb, wb) per bucket shape — and the plan-global fused
    checkpoint width ``wr`` for fused plans — and install the fastest
    tiling into the matrix's cached SpMVPlan. Returns (plan, records);
    each record is ``dict(bucket, sb, wb, seconds)`` (kernel sweep) or
    ``dict(wr, seconds)`` (width sweep). No-op for the 'jnp' variant (no
    tiles). Winners persist: every later ``ops.packsell_spmv`` /
    ``plan.spmv`` call with the same plan key dispatches the tuned
    tiling, and when ``store``/``fingerprint``/``store_key`` are given
    the winners are ALSO persisted backend-keyed in the precision store
    (``store.put_retile`` — a CPU interpret sweep never poisons a
    TPU/GPU selection).
    """
    plan = kplan.get_plan(mat, hw=hw, force=force, interpret=interpret)
    if plan.variant == "jnp":
        return plan, []
    records = []
    if plan.variant == "fused":
        # fused plans have no per-bucket kernel tiles to sweep; the knob
        # is the checkpoint width wr (group granularity + level depth)
        wrs = kplan._CKPT_WIDTHS if wrs is None else wrs
        best_wr, best_t = plan.fused_layout.wr, np.inf
        for wr in wrs:
            cand = kplan.build_plan(mat, hw=hw, force=force,
                                    interpret=interpret, ckpt_wr=wr)
            if cand.variant != "fused" or cand.fused_layout.wr != wr:
                continue            # infeasible at this width
            t = common.time_fn(lambda x, c=cand: c.spmv(mat, x), x,
                               warmup=1, repeats=repeats)
            records.append(dict(wr=int(wr), seconds=t))
            if t < best_t:
                best_wr, best_t = int(wr), t
        winners = [(sb, wb, best_wr) for sb, wb in plan.tiles]
        plan.retile(winners)
        if store is not None and fingerprint and store_key:
            store.put_retile(fingerprint, store_key, winners)
        return plan, records
    interp = plan.interpret
    winners = []
    for b, (pack, d0, maxcol) in enumerate(
            zip(mat.packs, mat.d0s, mat.maxcols)):
        best_tile, best_t = plan.tiles[b], np.inf
        for sb in sbs:
            for wb in wbs:
                if plan.variant == "band":
                    win = kplan.bucket_band_windows(d0, maxcol, sb, hw)
                    if win is None:
                        continue
                    winj = jnp.asarray(win)

                    def fn(x, pack=pack, d0=d0, winj=winj, sb=sb, wb=wb):
                        return _pk.packsell_spmv_band_bucket(
                            pack, d0, winj, x, codec_name=mat.codec_name,
                            D=mat.D, hw=hw, sb=sb, wb=wb, interpret=interp)
                else:
                    def fn(x, pack=pack, d0=d0, sb=sb, wb=wb):
                        return _pk.packsell_spmv_bucket(
                            pack, d0, x, codec_name=mat.codec_name,
                            D=mat.D, sb=sb, wb=wb, interpret=interp)

                t = common.time_fn(jax.jit(fn), x, warmup=1,
                                   repeats=repeats)
                records.append(dict(bucket=b, sb=sb, wb=wb, seconds=t))
                if t < best_t:
                    best_tile, best_t = (sb, wb), t
        winners.append(best_tile)
    plan.retile(winners)
    if store is not None and fingerprint and store_key:
        store.put_retile(fingerprint, store_key, winners)
    return plan, records


# ---------------------------------------------------------------------------
# Dispatch: plan-cached single dispatch vs the seed per-call path
# ---------------------------------------------------------------------------


def _seed_percall_spmv(mat: pk.PackSELLMatrix, x: jnp.ndarray) -> jnp.ndarray:
    """The pre-plan hot path, reproduced for comparison: re-run host-side
    band planning, then the eager sequential-decode SpMV with one
    full-length σ-scatter per width bucket (what the seed's solver matvecs
    executed on every call)."""
    kplan.band_plan(mat, 8, 4096)
    return pk.packsell_spmv_jnp(mat, x, decode="loop")


def bench_dispatch(scale: str) -> None:
    suite = testmats.suite(scale)
    for name, a in suite.items():
        x = jnp.asarray(
            np.random.default_rng(3).standard_normal(a.shape[1])
            .astype(np.float32))
        mat = pk.from_csr(a, C=32, sigma=256, D=15, codec="fp16")
        plan = kplan.get_plan(mat)
        t_cached = common.time_fn(lambda x: plan.spmv(mat, x), x,
                                  warmup=2, repeats=5)
        t_seed = common.time_fn(lambda x: _seed_percall_spmv(mat, x), x,
                                warmup=1, repeats=3)
        st = plan.decode_cache_stats()
        fmt = mat.memory_stats()
        common.emit("dispatch", name,
                    t_plan_cached_s=t_cached, t_seed_percall_s=t_seed,
                    speedup=t_seed / t_cached, variant=plan.variant,
                    decode_cache=st["cache_mode"],
                    decode_cache_bytes=st["decode_cache_bytes"],
                    full_cursor_bytes=st["full_cursor_bytes"],
                    decode_cache_shrink=round(st["shrink_vs_full"], 2),
                    format_bytes_per_nnz=round(
                        fmt["packsell_bytes"] / max(mat.nnz, 1), 3),
                    cache=str(kplan.cache_stats()["hits"]) + "h")


def run(scale: str | None = None) -> None:
    suite = testmats.suite("tiny")
    for name, a in suite.items():
        x = jnp.asarray(
            np.random.default_rng(1).standard_normal(a.shape[1])
            .astype(np.float32))
        for codec, D in (("fp16", 15), ("bf16", 15), ("e8m", 8)):
            mat = pk.from_csr(a, C=128, sigma=256, D=D, codec=codec,
                              bucket_strategy="uniform")
            oracle = pk.packsell_spmv_jnp(mat, x)
            y = ops.packsell_spmv(mat, x, force="full")
            err = float(jnp.max(jnp.abs(y - oracle)))
            wins = ops.band_plan(mat, sb=8, hw=4096)
            rec = dict(max_abs_err_full=err,
                       vmem_full_kb=_vmem_bytes(mat, 8, 32, True) / 1024)
            if wins is not None:
                yb = ops.packsell_spmv(mat, x, force="band")
                rec["max_abs_err_band"] = float(jnp.max(jnp.abs(yb - oracle)))
                rec["vmem_band_kb"] = _vmem_bytes(mat, 8, 32, False) / 1024
            common.emit("kernel_check", f"{name}_{codec}_D{D}", **rec)

    # autotune the full-x kernel tiling on a banded tiny matrix and report
    # the per-bucket winners the plan will dispatch from now on
    a = testmats.random_banded(2048, 40, 8, seed=11)
    mat = pk.from_csr(a, C=128, sigma=256, D=15, codec="fp16",
                      bucket_strategy="uniform")
    x = jnp.asarray(np.random.default_rng(2).standard_normal(a.shape[1])
                    .astype(np.float32))
    plan, records = autotune(mat, x, force="full")
    for b, (sb, wb) in enumerate(plan.tiles):
        trials = [r for r in records if r["bucket"] == b]
        common.emit("autotune", f"banded_bucket{b}", sb=sb, wb=wb,
                    best_s=min(r["seconds"] for r in trials),
                    worst_s=max(r["seconds"] for r in trials),
                    n_trials=len(trials))

    bench_dispatch(scale or common.SCALE)
