"""Fig. 5/6/8 analogue: FP16 SpMV across formats and matrix classes.

PackSELL (W=32, D=15, fp16 embed) vs SELL-fp16 (cuSELL analogue) vs
CSR-fp16 (cuCSR analogue) vs COO-fp16, per structural matrix class.
Reports effective GFLOPS (2·nnz / t, padding excluded — paper §5.1) and
the PackSELL speedups of Fig. 8.

Also benchmarks the execution-engine changes per matrix class — the
scan-parallel cumsum decode vs the seed ``fori_loop`` word walk, and
cold (plan build + trace) vs plan-cached dispatch — and records them in
``BENCH_spmv.json`` at the repo root so later PRs have a perf trajectory.
"""
from __future__ import annotations

import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import packsell as pk
from repro.core import sell as sl
from repro.core import sparse as sps
from repro.core import testmats
from repro.kernels import plan as kplan

from . import common

_JSON_PATH = os.environ.get(
    "REPRO_BENCH_SPMV_JSON",
    os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                 "BENCH_spmv.json"))


def _bench_engine(name: str, a, x: jnp.ndarray) -> dict:
    """Per-matrix engine numbers: the seed fori_loop spmv vs the engine's
    cumsum-decode dispatch, and dispatch cold-vs-cached."""
    mat = pk.from_csr(a, C=32, sigma=256, D=15, codec="fp16")

    # seed decode path: the sequential fori_loop word walk with per-bucket
    # σ-scatter, jitted with the matrix as an *argument* (not a closure
    # constant, so XLA cannot constant-fold any of it away).
    f_loop = jax.jit(lambda mat, x: pk.packsell_spmv_jnp(mat, x,
                                                         decode="loop"))
    t_loop = common.time_fn(f_loop, mat, x)

    # engine scan path: cumsum column decode — run once at plan build (the
    # plan's cursor cache) — then value-unpack + gather + reduce per call,
    # with the fused inverse-permutation epilogue. Cold = plan build + first
    # traced call; cached = steady-state single-dispatch calls.
    kplan.clear_cache()
    t0 = time.perf_counter()
    plan = kplan.get_plan(mat)
    jax.block_until_ready(plan.spmv(mat, x))
    t_cold = time.perf_counter() - t0
    t_scan = common.time_fn(lambda x: plan.spmv(mat, x), x)

    rec = dict(
        decode_loop_s=t_loop, decode_scan_s=t_scan,
        decode_speedup=t_loop / t_scan,
        dispatch_cold_s=t_cold, dispatch_cached_s=t_scan,
        plan_variant=plan.variant,
    )
    common.emit("spmv_engine", name, **rec)
    return rec


def run(scale: str | None = None) -> None:
    scale = scale or common.SCALE
    suite = testmats.suite(scale)
    C, sigma = 32, 256
    engine_rows = {}
    for name, a in suite.items():
        n, m = a.shape
        nnz = a.nnz
        rng = np.random.default_rng(7)
        x = jnp.asarray(rng.standard_normal(m).astype(np.float32))

        mats = {
            "packsell_fp16": pk.from_csr(a, C=C, sigma=sigma, D=15,
                                         codec="fp16"),
            "sell_fp16": sl.from_csr(a, C=C, sigma=sigma,
                                     value_dtype="float16"),
            "csr_fp16": sps.csr_from_scipy(a, "float16"),
            "coo_fp16": sps.coo_from_scipy(a, "float16"),
        }
        fns = {
            "packsell_fp16": jax.jit(
                lambda x, mm=mats["packsell_fp16"]: pk.packsell_spmv_jnp(
                    mm, x)),
            "sell_fp16": jax.jit(
                lambda x, mm=mats["sell_fp16"]: sl.sell_spmv_jnp(mm, x)),
            "csr_fp16": jax.jit(
                lambda x, mm=mats["csr_fp16"]: mm.spmv(x)),
            "coo_fp16": jax.jit(
                lambda x, mm=mats["coo_fp16"]: mm.spmv(x)),
        }
        times, gflops = {}, {}
        for k, fn in fns.items():
            t = common.time_fn(fn, x)
            times[k] = t
            gflops[k] = 2.0 * nnz / t / 1e9
        ps = mats["packsell_fp16"]
        row_nnz = np.diff(a.indptr)
        rsd = float(np.std(row_nnz) / max(np.mean(row_nnz), 1e-300))
        common.emit(
            "spmv_fp16", name, n=n, nnz=nnz, rsd=round(rsd, 4),
            gflops_packsell=gflops["packsell_fp16"],
            gflops_sell=gflops["sell_fp16"],
            gflops_csr=gflops["csr_fp16"],
            gflops_coo=gflops["coo_fp16"],
            speedup_vs_sell=times["sell_fp16"] / times["packsell_fp16"],
            speedup_vs_csr=times["csr_fp16"] / times["packsell_fp16"],
            n_dummy=ps.n_dummy,
        )
        engine_rows[name] = dict(n=n, nnz=nnz, **_bench_engine(name, a, x))

    payload = dict(
        scale=scale, backend=jax.default_backend(),
        note=("cold = plan build + first traced dispatch; cached = "
              "steady-state single-dispatch calls; decode timings are "
              "jitted loop vs cumsum-scan column decode"),
        cases=engine_rows,
    )
    with open(_JSON_PATH, "w") as f:
        json.dump(payload, f, indent=1, default=float)
        f.write("\n")
    print(f"[bench_spmv] wrote {_JSON_PATH}")
