"""Fig. 5/6/8 analogue: FP16 SpMV across formats and matrix classes.

PackSELL (W=32, D=15, fp16 embed) vs SELL-fp16 (cuSELL analogue) vs
CSR-fp16 (cuCSR analogue) vs COO-fp16, per structural matrix class.
Reports effective GFLOPS (2·nnz / t, padding excluded — paper §5.1) and
the PackSELL speedups of Fig. 8.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import packsell as pk
from repro.core import sell as sl
from repro.core import sparse as sps
from repro.core import testmats

from . import common


def run(scale: str | None = None) -> None:
    scale = scale or common.SCALE
    suite = testmats.suite(scale)
    C, sigma = 32, 256
    for name, a in suite.items():
        n, m = a.shape
        nnz = a.nnz
        rng = np.random.default_rng(7)
        x = jnp.asarray(rng.standard_normal(m).astype(np.float32))

        mats = {
            "packsell_fp16": pk.from_csr(a, C=C, sigma=sigma, D=15,
                                         codec="fp16"),
            "sell_fp16": sl.from_csr(a, C=C, sigma=sigma,
                                     value_dtype="float16"),
            "csr_fp16": sps.csr_from_scipy(a, "float16"),
            "coo_fp16": sps.coo_from_scipy(a, "float16"),
        }
        fns = {
            "packsell_fp16": jax.jit(
                lambda x, mm=mats["packsell_fp16"]: pk.packsell_spmv_jnp(
                    mm, x)),
            "sell_fp16": jax.jit(
                lambda x, mm=mats["sell_fp16"]: sl.sell_spmv_jnp(mm, x)),
            "csr_fp16": jax.jit(
                lambda x, mm=mats["csr_fp16"]: mm.spmv(x)),
            "coo_fp16": jax.jit(
                lambda x, mm=mats["coo_fp16"]: mm.spmv(x)),
        }
        times, gflops = {}, {}
        for k, fn in fns.items():
            t = common.time_fn(fn, x)
            times[k] = t
            gflops[k] = 2.0 * nnz / t / 1e9
        ps = mats["packsell_fp16"]
        row_nnz = np.diff(a.indptr)
        rsd = float(np.std(row_nnz) / max(np.mean(row_nnz), 1e-300))
        common.emit(
            "spmv_fp16", name, n=n, nnz=nnz, rsd=round(rsd, 4),
            gflops_packsell=gflops["packsell_fp16"],
            gflops_sell=gflops["sell_fp16"],
            gflops_csr=gflops["csr_fp16"],
            gflops_coo=gflops["coo_fp16"],
            speedup_vs_sell=times["sell_fp16"] / times["packsell_fp16"],
            speedup_vs_csr=times["csr_fp16"] / times["packsell_fp16"],
            n_dummy=ps.n_dummy,
        )
