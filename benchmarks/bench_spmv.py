"""Fig. 5/6/8 analogue: FP16 SpMV across formats and matrix classes.

PackSELL (W=32, D=15, fp16 embed) vs SELL-fp16 (cuSELL analogue) vs
CSR-fp16 (cuCSR analogue) vs COO-fp16, per structural matrix class.
Reports effective GFLOPS (2·nnz / t, padding excluded — paper §5.1) and
the PackSELL speedups of Fig. 8.

Also benchmarks the execution-engine trajectory per matrix class and
records it in ``BENCH_spmv.json`` at the repo root:

* the seed ``fori_loop`` word-walk decode (PR-0 baseline),
* the PR-1 cursor path, reproduced faithfully (per-bucket full cursor
  cache + fill-mode gathers + per-bucket loop + concat + inverse-perm
  gather) — 4 extra bytes streamed per stored word,
* the fused ragged checkpoint path (this PR, DESIGN.md §10): one
  word-stream operand, one int32 checkpoint per ``wr`` words, build-time
  prefix re-basing, unrolled accumulation.

The fused-vs-PR-1 comparison is measured INTERLEAVED
(:func:`benchmarks.common.time_fns`) so container noise cancels out of
the ratio, and both paths' outputs are checked equal (max |Δ| reported —
the accumulation order differs, the arithmetic does not). Decode-cache
device memory (checkpoints vs the full cursor cache) and effective
hot-stream bandwidth land next to the timings so the footprint win is
part of the trajectory.
"""
from __future__ import annotations

import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import codecs as cd
from repro.core import packsell as pk
from repro.core import sell as sl
from repro.core import sparse as sps
from repro.core import testmats
from repro.kernels import plan as kplan

from . import common

_JSON_PATH = os.environ.get(
    "REPRO_BENCH_SPMV_JSON",
    os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                 "BENCH_spmv.json"))


def _pr1_cursor_spmv(packs, colss, x, inv, mlim, codec, D):
    """The PR-1 hot path, reproduced for the trajectory comparison: one
    full int32 cursor per stored word streamed next to the packs,
    minimum-clamp + default (fill-mode) gathers, per-bucket unpack/gather/
    reduce with a concat epilogue, then the inverse-permutation gather."""
    xc = x.astype(jnp.float32)
    parts = []
    for pack, cols in zip(packs, colss):
        S, w, C = pack.shape
        v, _ = cd.unpack_words_jnp(pack, codec, D)
        xv = jnp.take(xc, jnp.minimum(cols, mlim).reshape(-1),
                      axis=0).reshape(S, w, C)
        parts.append(jnp.sum(v.astype(jnp.float32) * xv, axis=1).reshape(-1))
    t_cat = parts[0] if len(parts) == 1 else jnp.concatenate(parts)
    return jnp.take(t_cat, inv, axis=0)


def _bench_engine(name: str, a, x: jnp.ndarray) -> dict:
    """Per-matrix engine numbers: seed loop decode, PR-1 cursor path and
    the fused checkpoint path, plus decode-cache memory accounting."""
    mat = pk.from_csr(a, C=32, sigma=256, D=15, codec="fp16")
    codec = mat.codec

    # seed decode path: the sequential fori_loop word walk with per-bucket
    # σ-scatter, jitted with the matrix as an *argument* (not a closure
    # constant, so XLA cannot constant-fold any of it away).
    f_loop = jax.jit(lambda mat, x: pk.packsell_spmv_jnp(mat, x,
                                                         decode="loop"))
    t_loop = common.time_fn(f_loop, mat, x)

    # fused checkpoint path: cold = plan build + first traced call;
    # cached = steady-state single-dispatch calls.
    kplan.clear_cache()
    t0 = time.perf_counter()
    plan = kplan.get_plan(mat, decode_cache="checkpoint")
    jax.block_until_ready(plan.spmv(mat, x))
    t_cold = time.perf_counter() - t0

    # PR-1 replica operands: the full cursor cache of the same plan engine
    plan_cur = kplan.build_plan(mat, force="jnp", decode_cache="full")
    mlim = np.int32(max(mat.m - 1, 0))
    pr1 = jax.jit(lambda packs, cols, x, inv:
                  _pr1_cursor_spmv(packs, cols, x, inv, mlim, codec, 15))

    y_fused = np.asarray(plan.spmv(mat, x))
    y_pr1 = np.asarray(pr1(mat.packs, plan_cur.cols, x, plan_cur.inv_cat))
    scale = max(float(np.max(np.abs(y_pr1))), 1e-30)
    max_rel_diff = float(np.max(np.abs(y_fused - y_pr1))) / scale

    ts = common.time_fns(
        {"fused": lambda x: plan.spmv(mat, x),
         "pr1": lambda x: pr1(mat.packs, plan_cur.cols, x,
                              plan_cur.inv_cat)},
        {"fused": (x,), "pr1": (x,)},
        rounds=25, samples=True)
    t_fused = float(np.median(ts["fused"]))
    t_pr1 = float(np.median(ts["pr1"]))
    speedup = common.paired_speedup(ts, "pr1", "fused")

    # pallas-fused variant: the same stream through the fused Pallas
    # kernel (interpret mode off-TPU — a correctness/variant column
    # there, a real timing on compiled backends). Few rounds: interpret
    # mode is Python-speed.
    t_pallas = pallas_vs_jnp = None
    plan_pl = kplan.build_plan(mat, force="fused")
    if plan_pl.variant == "fused":
        tsp = common.time_fns(
            {"jnpf": lambda x: plan.spmv(mat, x),
             "pallas": lambda x: plan_pl.spmv(mat, x)},
            {"jnpf": (x,), "pallas": (x,)},
            rounds=5, samples=True)
        t_pallas = float(np.median(tsp["pallas"]))
        pallas_vs_jnp = common.paired_speedup(tsp, "jnpf", "pallas")

    st = plan.decode_cache_stats()
    lay = plan.fused_layout
    nnz = max(mat.nnz, 1)
    # steady-state hot-stream traffic: the word stream (+ decode cache)
    # each matvec reads, x read once, y written once
    fused_traffic = st["fused_stream_bytes"] + st["decode_cache_bytes"] \
        + 4 * (mat.m + mat.n)
    pr1_traffic = 4 * plan.total_words + st["full_cursor_bytes"] \
        + 4 * (mat.m + mat.n)

    rec = dict(
        decode_loop_s=t_loop,
        dispatch_cold_s=t_cold,
        dispatch_cached_s=t_fused,
        pr1_cursor_s=t_pr1,
        fused_speedup_vs_pr1=speedup,
        fused_speedup_vs_seed_loop=t_loop / t_fused,
        max_rel_diff_vs_pr1=max_rel_diff,
        plan_variant=plan.variant,
        plan_variant_pallas=plan_pl.variant,
        pallas_fused_s=t_pallas,
        pallas_vs_jnp=pallas_vs_jnp,
        decode_cache_mode=st["cache_mode"],
        fused_encoding=None if lay is None else lay.encoding,
        ckpt_width=None if lay is None else lay.wr,
        decode_cache_bytes=st["decode_cache_bytes"],
        pr1_cursor_cache_bytes=st["full_cursor_bytes"],
        decode_cache_shrink=st["shrink_vs_full"],
        fused_stream_bytes=st["fused_stream_bytes"],
        stream_bytes_per_nnz=(st["fused_stream_bytes"]
                              + st["decode_cache_bytes"]) / nnz,
        pr1_stream_bytes_per_nnz=(4 * plan.total_words
                                  + st["full_cursor_bytes"]) / nnz,
        fused_bandwidth_gbs=fused_traffic / t_fused / 1e9,
        pr1_bandwidth_gbs=pr1_traffic / t_pr1 / 1e9,
    )
    common.emit("spmv_engine", name, **rec)
    return rec


def run(scale: str | None = None) -> None:
    scale = scale or common.SCALE
    suite = testmats.suite(scale)
    C, sigma = 32, 256
    engine_rows = {}
    for name, a in suite.items():
        n, m = a.shape
        nnz = a.nnz
        rng = np.random.default_rng(7)
        x = jnp.asarray(rng.standard_normal(m).astype(np.float32))

        mats = {
            "packsell_fp16": pk.from_csr(a, C=C, sigma=sigma, D=15,
                                         codec="fp16"),
            "sell_fp16": sl.from_csr(a, C=C, sigma=sigma,
                                     value_dtype="float16"),
            "csr_fp16": sps.csr_from_scipy(a, "float16"),
            "coo_fp16": sps.coo_from_scipy(a, "float16"),
        }
        fns = {
            "packsell_fp16": jax.jit(
                lambda x, mm=mats["packsell_fp16"]: pk.packsell_spmv_jnp(
                    mm, x)),
            "sell_fp16": jax.jit(
                lambda x, mm=mats["sell_fp16"]: sl.sell_spmv_jnp(mm, x)),
            "csr_fp16": jax.jit(
                lambda x, mm=mats["csr_fp16"]: mm.spmv(x)),
            "coo_fp16": jax.jit(
                lambda x, mm=mats["coo_fp16"]: mm.spmv(x)),
        }
        times, gflops = {}, {}
        for k, fn in fns.items():
            t = common.time_fn(fn, x)
            times[k] = t
            gflops[k] = 2.0 * nnz / t / 1e9
        ps = mats["packsell_fp16"]
        row_nnz = np.diff(a.indptr)
        rsd = float(np.std(row_nnz) / max(np.mean(row_nnz), 1e-300))
        common.emit(
            "spmv_fp16", name, n=n, nnz=nnz, rsd=round(rsd, 4),
            gflops_packsell=gflops["packsell_fp16"],
            gflops_sell=gflops["sell_fp16"],
            gflops_csr=gflops["csr_fp16"],
            gflops_coo=gflops["coo_fp16"],
            speedup_vs_sell=times["sell_fp16"] / times["packsell_fp16"],
            speedup_vs_csr=times["csr_fp16"] / times["packsell_fp16"],
            n_dummy=ps.n_dummy,
        )
        engine_rows[name] = dict(n=n, nnz=nnz, **_bench_engine(name, a, x))

    payload = dict(
        scale=scale, backend=jax.default_backend(),
        note=("cold = plan build + first traced dispatch; cached = "
              "steady-state fused-checkpoint single-dispatch calls; "
              "pr1_cursor_s = the PR-1 full-cursor-cache path replayed "
              "and timed interleaved with the fused path (ratios are "
              "noise-robust); decode_cache_* price the per-matvec decode "
              "stream (checkpoints vs one int32 cursor per word)"),
        cases=engine_rows,
    )
    common.save_bench_json(_JSON_PATH, payload)
