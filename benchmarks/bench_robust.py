"""Guarded-execution benchmarks (DESIGN.md §11.5).

Three questions, answered with numbers:

1. **Overhead** — what does the guard cost per matvec? Exact reductions
   cost ~0.2 ns/word on this backend — comparable to the SpMV itself on
   very sparse matrices — so the full check (ABFT identity + exact
   operand checksum) is amortized over a stride: every K-th guarded call
   runs it, the rest run a fused ``all(isfinite(y))`` check
   (``GuardState.every``; env ``REPRO_GUARD_EVERY``). Paired timings
   plain vs light vs full per suite matrix; ``overhead_pct`` is the
   steady-state amortized figure at ``guard_every`` (target: <= 5%).
2. **Detection** — across a seeded injection campaign (fused-word bit
   flips, checkpoint shifts, permutation swaps, pack-word flips on the
   non-fused paths), what fraction of *value-affecting* single-word
   corruptions does the guard catch? (target: >= 99%; the exact checksum
   makes this 100% by construction — the campaign verifies the
   construction.)
3. **Recovery** — does ``guarded_solve`` still reach 1e-8 true relative
   residual on every suite class with a fault injected mid-solve, and
   which escalation did it take?

Writes ``BENCH_robust.json`` at the repo root.
"""
from __future__ import annotations

import os

import jax.numpy as jnp
import numpy as np
import scipy.sparse as sp

from repro.core import packsell as pk
from repro.core import testmats
from repro.kernels import plan as kplan
from repro.robust import guard as gd
from repro.robust import inject as inj
from repro.robust import recover as rc
from repro.solvers.operators import OperatorSet

from . import common

_JSON_PATH = os.environ.get(
    "REPRO_BENCH_ROBUST_JSON",
    os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                 "BENCH_robust.json"))

#: per-matrix seeded injections per injector in the detection campaign
_CAMPAIGN_PER_INJECTOR = 10

#: steady-state full-guard stride reported as the amortized overhead
#: figure (detection latency for silent operand corruption <= this many
#: matvecs; NaN/Inf poisoning is still caught on every call)
_GUARD_EVERY = int(os.environ.get("REPRO_GUARD_EVERY", "128"))


def _spd(a: sp.csr_matrix) -> sp.csr_matrix:
    s = ((a + a.T) / 2).tocsr()
    shift = float(np.abs(s).sum(axis=1).max())
    return (s + sp.eye(s.shape[0]) * shift).tocsr()


def _overhead(name: str, a) -> dict:
    mat = pk.from_csr(a.tocsr(), C=32, sigma=256, codec="fp16")
    plan = kplan.get_plan(mat)
    gs = gd.build_guard(mat, plan, every=_GUARD_EVERY)
    x = jnp.asarray(
        np.random.default_rng(7).standard_normal(mat.m), jnp.float32)

    reps = 4   # calls per timing sample: averages out per-call host jitter

    def _rep(f):
        def g(v):
            for _ in range(reps - 1):
                f(v)
            return f(v)
        return g

    ts = common.time_fns(
        {"plain": _rep(lambda v: plan.spmv(mat, v)),
         "light": _rep(
             lambda v: gd.guarded_spmv(mat, plan, gs, v, full=False)),
         "full": _rep(
             lambda v: gd.guarded_spmv(mat, plan, gs, v, full=True))},
        {"plain": (x,), "light": (x,), "full": (x,)}, warmup=2, rounds=15,
        samples=True)
    r_light = common.paired_speedup(ts, "light", "plain")   # t_l / t_p
    r_full = common.paired_speedup(ts, "full", "plain")     # t_f / t_p
    # steady state: 1 full + (K-1) light calls per stride window
    k = gs.every
    r_amort = (r_full + (k - 1) * r_light) / k
    row = dict(t_plain_us=float(np.median(ts["plain"])) * 1e6 / reps,
               t_light_us=float(np.median(ts["light"])) * 1e6 / reps,
               t_full_us=float(np.median(ts["full"])) * 1e6 / reps,
               guard_every=k,
               overhead_light_pct=(r_light - 1.0) * 100.0,
               overhead_full_pct=(r_full - 1.0) * 100.0,
               overhead_pct=(r_amort - 1.0) * 100.0)
    common.emit("robust_overhead", name, **row)
    return row


def _campaign(name: str, a) -> dict:
    """Seeded injections on the fused-jnp plan AND the 'full' cursor-cache
    plan (the non-fused execution path); every value-affecting corruption
    must trip the guard."""
    counts = dict(total=0, affecting=0, detected=0, neutral_flagged=0)

    def trial(mat, plan, gs, x, injection):
        _, ok, _ = gd.guarded_spmv(mat, plan, gs, x)
        tripped = not bool(ok)
        counts["total"] += 1
        if not injection.value_neutral:
            counts["affecting"] += 1
            counts["detected"] += tripped
        elif tripped:
            counts["neutral_flagged"] += 1   # checksum sees even these
        injection.undo()

    # fused-jnp plan (the CPU hot path)
    mat = pk.from_csr(a.tocsr(), C=32, sigma=64, codec="fp16")
    plan = kplan.get_plan(mat)
    gs = gd.build_guard(mat, plan)
    x = jnp.asarray(
        np.random.default_rng(3).standard_normal(mat.m), jnp.float32)
    for seed in range(_CAMPAIGN_PER_INJECTOR):
        if plan.fused is not None:
            trial(mat, plan, gs, x, inj.flip_fused_word(mat, plan, seed))
            trial(mat, plan, gs, x,
                  inj.corrupt_fused_checkpoint(mat, plan, seed))
        trial(mat, plan, gs, x, inj.corrupt_permutation(mat, plan, seed))

    # 'full' cursor-cache plan (bucketed packs are the live operands)
    mat2 = pk.from_csr(a.tocsr(), C=32, sigma=64, codec="fp16")
    plan2 = kplan.get_plan(mat2, decode_cache="full")
    gs2 = gd.build_guard(mat2, plan2)
    for seed in range(_CAMPAIGN_PER_INJECTOR):
        trial(mat2, plan2, gs2, x, inj.flip_pack_word(mat2, plan2, seed))

    rate = (counts["detected"] / counts["affecting"]
            if counts["affecting"] else 1.0)
    row = dict(injections=counts["total"], affecting=counts["affecting"],
               detected=counts["detected"], detection_rate=rate,
               neutral_flagged=counts["neutral_flagged"])
    common.emit("robust_detection", name, **row)
    return row


def _recovery(name: str, a) -> dict:
    ops = OperatorSet(_spd(a), C=32, sigma=64)
    n = ops.n
    b = np.random.default_rng(17).standard_normal(n)
    fired = []

    def sabotage(step, ctx):
        if step == 1 and not fired and ctx["plan"] is not None \
                and ctx["plan"].fused is not None:
            fired.append(inj.flip_fused_word(ctx["mat"], ctx["plan"],
                                             seed=19, bit=27))

    x, info = rc.guarded_solve(ops, "guarded:plan_fp16", b, tol=1e-8,
                               maxiter=80, m_in=16, on_step=sabotage)
    true_rel = float(np.linalg.norm(b - ops.csr.astype(np.float64) @ x)
                     / np.linalg.norm(b))
    row = dict(true_relres=true_rel, reached_1e8=true_rel <= 1e-8,
               steps=info.iters, trips=info.trips,
               fault_fired=bool(fired),
               escalations="|".join(e["action"] for e in info.log),
               final_kind=info.final_kind)
    common.emit("robust_recovery", name, **row)
    return row


def run(scale: str | None = None) -> None:
    scale = scale or common.SCALE
    # overhead on the benchmark-scale suite; campaign + recovery on the
    # tiny suite (detection is a per-word property — size-independent)
    over_suite = testmats.suite("tiny" if scale == "tiny" else "small")
    over = [_overhead(name, a) for name, a in over_suite.items()]
    common.emit(
        "robust_overhead", "ALL", guard_every=_GUARD_EVERY,
        overhead_pct=float(np.median([r["overhead_pct"] for r in over])),
        overhead_full_pct=float(
            np.median([r["overhead_full_pct"] for r in over])),
        overhead_light_pct=float(
            np.median([r["overhead_light_pct"] for r in over])))

    tiny = testmats.suite("tiny")
    agg = dict(affecting=0, detected=0, injections=0)
    for name, a in tiny.items():
        row = _campaign(name, a)
        agg["affecting"] += row["affecting"]
        agg["detected"] += row["detected"]
        agg["injections"] += row["injections"]
    common.emit("robust_detection", "ALL",
                injections=agg["injections"], affecting=agg["affecting"],
                detected=agg["detected"],
                detection_rate=(agg["detected"] / agg["affecting"]
                                if agg["affecting"] else 1.0))

    for name, a in tiny.items():
        _recovery(name, a)

    rows = [r for r in common.rows() if r["bench"].startswith("robust")]
    common.save_bench_json(_JSON_PATH, rows)


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", default=None)
    run(ap.parse_args().scale)
