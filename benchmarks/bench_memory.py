"""Fig. 7 analogue: PackSELL / SELL memory-footprint ratio per matrix class.

The paper's lower bound for the fp16 embed is 32 / 48 bits = 0.667 against
fp16+int32 SELL (and 0.75 against the fp32 pack stream comparison in the
text). Dummy elements and σ-padding move the ratio up; scattered matrices
can exceed 1.0 — exactly the Fig. 7 story. Also reports the bucket-padding
overhead our TPU layout adds (DESIGN.md §2) so the adaptation cost is
visible and accounted.

Post-PR-5 the hot path is the *plan*, not the raw format arrays, so the
main rows also carry the plan-backed accounting the roofline scoreboard
uses: ``plan.as_composite(mat).memory_stats()`` (resident composite
bytes) and ``plan.decode_cache_stats()`` (the fused word stream + decode
cache the dispatch actually reads).  Writes ``BENCH_memory.json``.
"""
from __future__ import annotations

import os

from repro import observe
from repro.core import packsell as pk
from repro.core import sell as sl
from repro.core import testmats
from repro.kernels import plan as kplan

from . import common

_JSON_PATH = os.environ.get(
    "REPRO_BENCH_MEMORY_JSON",
    os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                 "BENCH_memory.json"))


def _plan_stats(mat) -> dict:
    """Hot-path byte accounting for one packed matrix: what the cached
    dispatch is resident in (composite) and what it streams per call."""
    plan = kplan.get_plan(mat)
    dcs = plan.decode_cache_stats()
    fmt = plan.as_composite(mat).memory_stats()
    stream = (dcs["fused_stream_bytes"] or 4 * plan.total_words) \
        + dcs["decode_cache_bytes"]
    return {
        "variant": plan.variant,
        "cache_mode": plan.cache_mode,
        "composite_bytes": int(fmt["composite_bytes"]),
        "composite_bytes_per_nnz": fmt["bytes_per_nnz"],
        "stream_bytes": int(stream),
    }


def run(scale: str | None = None) -> None:
    scale = scale or common.SCALE
    suite = testmats.suite(scale)
    C, sigma = 32, 256
    prev = observe.enable(True)
    rows = []
    try:
        for name, a in suite.items():
            ps = pk.from_csr(a, C=C, sigma=sigma, D=15, codec="fp16")
            se = sl.from_csr(a, C=C, sigma=sigma, value_dtype="float16",
                             device=False)
            ms_p = ps.memory_stats()
            ms_s = se.memory_stats()
            ratio = ms_p["packsell_bytes"] / ms_s["sell_bytes"]
            rows.append(common.emit(
                "memory_ratio", name,
                nnz=a.nnz,
                packsell_bytes=ms_p["packsell_bytes"],
                sell_bytes=ms_s["sell_bytes"],
                ratio=ratio,
                dummy_frac=ps.n_dummy / max(a.nnz, 1),
                bucket_overhead_frac=ms_p["bucket_overhead_bytes"]
                / max(ms_p["packsell_bytes"], 1),
                **_plan_stats(ps),
            ))

            # D sweep for the e8m codec (memory side of Fig. 9)
            for D in (1, 4, 8, 12):
                pe = pk.from_csr(a, C=C, sigma=sigma, D=D, codec="e8m")
                rows.append(common.emit(
                    "memory_ratio_e8m", f"{name}_D{D}",
                    ratio=pe.memory_stats()["packsell_bytes"]
                    / ms_s["sell_bytes"],
                    dummy_frac=pe.n_dummy / max(a.nnz, 1),
                    **_plan_stats(pe),
                ))

        # RCM reordering (paper §5.1.1 future work): locality recovery on
        # the scattered/powerlaw classes — dummy fraction and footprint
        # before/after
        from repro.core import reorder
        for name, a in suite.items():
            if a.shape[0] != a.shape[1]:
                continue
            sym = (a + a.T).tocsr()
            ar, _ = reorder.rcm_reorder(sym)
            for tag, mat in (("orig", sym), ("rcm", ar)):
                pe = pk.from_csr(mat, C=C, sigma=sigma, D=6, codec="e8m",
                                 device=False)
                se = sl.from_csr(mat, C=C, sigma=sigma,
                                 value_dtype="float16", device=False)
                rows.append(common.emit(
                    "memory_rcm", f"{name}_{tag}",
                    bandwidth=reorder.bandwidth(mat),
                    dummy_frac=pe.n_dummy / max(mat.nnz, 1),
                    ratio=pe.memory_stats()["packsell_bytes"]
                    / se.memory_stats()["sell_bytes"],
                ))
        common.save_bench_json(_JSON_PATH, {"scale": scale, "rows": rows})
    finally:
        observe.enable(prev)


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", default=None)
    run(ap.parse_args().scale)
