"""Distributed SpMV / PCG scaling over simulated host devices (DESIGN.md §7).

Strong scaling: one fixed matrix partitioned over 1/2/4/8 shards; weak
scaling: per-shard problem size held constant while the fleet grows. Both
sweep the two halo-exchange modes and record the distributed Jacobi-PCG
(time, iterations — iteration counts must not drift with the shard count).

JAX fixes the device count at backend initialization, so ``run`` re-executes
this module in a subprocess with
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` and folds the child's
rows back into the shared results. Simulated host devices share one CPU:
the curves measure dispatch + partition overheads and communication-volume
effects, not real interconnect bandwidth (DESIGN.md §2.5's relative-
instrument caveat applies doubly here).

Writes ``BENCH_distributed.json`` at the repo root, next to
``BENCH_spmv.json``.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

N_DEV = 8
SHARD_COUNTS = (1, 2, 4, 8)
_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
_JSON_PATH = os.environ.get("REPRO_BENCH_DIST_JSON",
                            os.path.join(_ROOT, "BENCH_distributed.json"))


def run(scale: str | None = None) -> None:
    """Parent entry point (benchmarks.run): spawn the forced-device-count
    child, then re-ingest its rows."""
    from . import common
    scale = scale or common.SCALE
    env = os.environ.copy()
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") +
                        f" --xla_force_host_platform_device_count={N_DEV}"
                        ).strip()
    proc = subprocess.run(
        [sys.executable, "-m", "benchmarks.bench_distributed",
         "--scale", scale],
        env=env, cwd=_ROOT)
    if proc.returncode != 0:
        raise RuntimeError(f"bench_distributed child failed "
                           f"(exit {proc.returncode})")
    with open(_JSON_PATH) as f:
        payload = json.load(f)
    common.rows().extend(payload["rows"])


def _suite(scale: str):
    from repro.core import testmats
    if scale == "tiny":
        return testmats.hpcg(8, 8, 8), 6, (1e-5, 50)
    if scale == "small":
        return testmats.hpcg(16, 16, 16), 12, (1e-6, 200)
    return testmats.hpcg(24, 24, 24), 16, (1e-6, 200)     # medium


def _child(scale: str) -> None:
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core import testmats
    from repro.distributed import build_dist_plan
    from repro.solvers import cg
    from repro.solvers import operators as op

    from . import common

    ndev = jax.device_count()
    a_strong, weak_side, (tol, maxiter) = _suite(scale)
    s_strong, _ = op.sym_scale(a_strong)
    rng = np.random.default_rng(0)
    x = rng.standard_normal(s_strong.shape[0]).astype(np.float32)
    b = jnp.asarray(rng.standard_normal(s_strong.shape[0]))

    base_t = {}
    for P in SHARD_COUNTS:
        if P > ndev:
            continue
        for mode in ("ppermute", "all_gather"):
            dplan = build_dist_plan(s_strong, P, C=32, sigma=256, D=15,
                                    codec="fp16", exchange=mode)
            xs = dplan.shard_vector(x)
            t = common.time_fn(
                lambda xs=xs, dp=dplan, m=mode: dp.spmv_sharded(xs, mode=m),
                warmup=2, repeats=5)
            st = dplan.memory_stats()
            key = ("spmv", mode)
            base_t.setdefault(key, t)
            common.emit(
                "dist_strong_spmv", f"hpcg_p{P}_{mode}", shards=P,
                n=s_strong.shape[0], nnz=int(s_strong.nnz), t_spmv_s=t,
                speedup_vs_p1=base_t[key] / t,
                halo_entries=st["halo_entries"], h_pad=st["h_pad"])
            if mode == "ppermute":
                _, info = cg.jacobi_pcg_dist(dplan, s_strong.diagonal(), b,
                                             tol=tol, maxiter=maxiter,
                                             dtype=jnp.float64)
                t_pcg = common.time_fn(
                    lambda dp=dplan: cg.jacobi_pcg_dist(
                        dp, s_strong.diagonal(), b, tol=tol,
                        maxiter=maxiter, dtype=jnp.float64)[0],
                    warmup=1, repeats=3)
                key = ("pcg",)
                base_t.setdefault(key, t_pcg)
                common.emit(
                    "dist_strong_pcg", f"hpcg_p{P}", shards=P,
                    iters=int(info.iters), relres=float(info.relres),
                    t_solve_s=t_pcg, speedup_vs_p1=base_t[key] / t_pcg)

    # weak scaling: ~weak_side^3 rows per shard
    for P in SHARD_COUNTS:
        if P > ndev:
            continue
        a_w = testmats.hpcg(weak_side, weak_side, weak_side * P)
        s_w, _ = op.sym_scale(a_w)
        xw = np.random.default_rng(1).standard_normal(
            s_w.shape[0]).astype(np.float32)
        dplan = build_dist_plan(s_w, P, C=32, sigma=256, D=15, codec="fp16")
        xs = dplan.shard_vector(xw)
        t = common.time_fn(lambda xs=xs, dp=dplan: dp.spmv_sharded(xs),
                           warmup=2, repeats=5)
        base_t.setdefault("weak", t)
        common.emit(
            "dist_weak_spmv", f"hpcg_p{P}", shards=P, n=s_w.shape[0],
            nnz=int(s_w.nnz), t_spmv_s=t,
            efficiency_vs_p1=base_t["weak"] / t)

    payload = dict(
        scale=scale, backend=jax.default_backend(), devices=ndev,
        note=("simulated host devices share one CPU: curves measure "
              "dispatch/partition overhead and communication volume, not "
              "interconnect bandwidth; speedup_vs_p1 = t(P=1)/t(P)"),
        rows=common.rows(),
    )
    common.save_bench_json(_JSON_PATH, payload)


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", default=None)
    args = ap.parse_args()
    scale = args.scale or os.environ.get("REPRO_BENCH_SCALE", "small")
    _child(scale)
