"""Fig. 9 analogue: E8MY PackSELL SpMV — performance and backward error.

Sweeps the delta width D (mantissa Y = 22 − D) against FP32/FP16/BF16 SELL
with FP32 input/output vectors and the paper's row scaling G⁻¹A. Reports
median time, speedup over FP32 SELL, and the eq. (5) backward error.

The PackSELL side dispatches through the cached :mod:`repro.kernels.plan`
path — the same executable every other benchmark (and the serving layer)
runs — not the seed-era eager ``packsell_spmv_jnp``, so the sweep reflects
the shipped hot path.  Per-D timings are interleaved with the FP32 SELL
baseline (:func:`benchmarks.common.time_fns`) so the speedup column is a
paired ratio.  Writes ``BENCH_e8my.json``.
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp
import numpy as np

from repro import observe
from repro.core import packsell as pk
from repro.core import sell as sl
from repro.core import testmats
from repro.kernels import plan as kplan
from repro.solvers.operators import row_scale

from . import common

_JSON_PATH = os.environ.get(
    "REPRO_BENCH_E8MY_JSON",
    os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                 "BENCH_e8my.json"))

D_GRID = (1, 2, 4, 6, 8, 10, 12)


def run(scale: str | None = None) -> None:
    scale = scale or common.SCALE
    suite = testmats.suite(scale)
    C, sigma = 32, 256
    prev = observe.enable(True)
    rows = []
    try:
        for name, a0 in suite.items():
            a, _ = row_scale(a0)
            a = a.tocsr()
            a.sort_indices()
            rng = np.random.default_rng(11)
            x = jnp.asarray(
                rng.standard_normal(a.shape[1]).astype(np.float32))

            base = {}
            for kind, dt in (("fp32", "float32"), ("fp16", "float16"),
                             ("bf16", "bfloat16")):
                mm = sl.from_csr(a, C=C, sigma=sigma, value_dtype=dt)
                fn = jax.jit(lambda x, mm=mm: sl.sell_spmv_jnp(mm, x))
                t = common.time_fn(fn, x)
                be = common.backward_error(fn(x), a, np.asarray(x))
                base[kind] = t
                rows.append(common.emit(
                    "e8my_baseline", f"{name}_{kind}",
                    t_us=t * 1e6, backward_error=be))

            # all D columns + the fp32 SELL reference timed interleaved:
            # per-round pairing cancels container throughput drift out of
            # the speedup ratios (the PR-5 comparison discipline)
            mm32 = sl.from_csr(a, C=C, sigma=sigma, value_dtype="float32")
            ref = jax.jit(lambda x, mm=mm32: sl.sell_spmv_jnp(mm, x))
            mats = {D: pk.from_csr(a, C=C, sigma=sigma, D=D, codec="e8m")
                    for D in D_GRID}
            plans = {D: kplan.get_plan(mats[D]) for D in D_GRID}
            fns = {"sell_fp32": ref}
            fns.update({f"D{D}": (lambda v, m=mats[D], p=plans[D]:
                                  p.spmv(m, v)) for D in D_GRID})
            ts = common.time_fns(fns, {k: (x,) for k in fns},
                                 rounds=9, samples=True)
            for D in D_GRID:
                mat, plan = mats[D], plans[D]
                t = float(np.median(ts[f"D{D}"]))
                be = common.backward_error(plan.spmv(mat, x), a,
                                           np.asarray(x))
                rows.append(common.emit(
                    "e8my_sweep", f"{name}_D{D}",
                    mantissa=22 - D,
                    t_us=t * 1e6,
                    variant=plan.variant,
                    cache_mode=plan.cache_mode,
                    speedup_vs_fp32sell=common.paired_speedup(
                        ts, "sell_fp32", f"D{D}"),
                    speedup_vs_fp16sell=base["fp16"] / t,
                    backward_error=be,
                    dummy_frac=mat.n_dummy / max(a.nnz, 1),
                ))
        common.save_bench_json(_JSON_PATH, {"scale": scale, "rows": rows})
    finally:
        observe.enable(prev)


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", default=None)
    run(ap.parse_args().scale)
