"""Fig. 9 analogue: E8MY PackSELL SpMV — performance and backward error.

Sweeps the delta width D (mantissa Y = 22 − D) against FP32/FP16/BF16 SELL
with FP32 input/output vectors and the paper's row scaling G⁻¹A. Reports
median time, speedup over FP32 SELL, and the eq. (5) backward error.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import packsell as pk
from repro.core import sell as sl
from repro.core import testmats
from repro.solvers.operators import row_scale

from . import common

D_GRID = (1, 2, 4, 6, 8, 10, 12)


def run(scale: str | None = None) -> None:
    scale = scale or common.SCALE
    suite = testmats.suite(scale)
    C, sigma = 32, 256
    for name, a0 in suite.items():
        a, _ = row_scale(a0)
        a = a.tocsr()
        a.sort_indices()
        rng = np.random.default_rng(11)
        x = jnp.asarray(rng.standard_normal(a.shape[1]).astype(np.float32))

        base = {}
        for kind, dt in (("fp32", "float32"), ("fp16", "float16"),
                         ("bf16", "bfloat16")):
            mm = sl.from_csr(a, C=C, sigma=sigma, value_dtype=dt)
            fn = jax.jit(lambda x, mm=mm: sl.sell_spmv_jnp(mm, x))
            t = common.time_fn(fn, x)
            be = common.backward_error(fn(x), a, np.asarray(x))
            base[kind] = t
            common.emit("e8my_baseline", f"{name}_{kind}",
                        t_us=t * 1e6, backward_error=be)

        for D in D_GRID:
            mm = pk.from_csr(a, C=C, sigma=sigma, D=D, codec="e8m")
            fn = jax.jit(lambda x, mm=mm: pk.packsell_spmv_jnp(mm, x))
            t = common.time_fn(fn, x)
            be = common.backward_error(fn(x), a, np.asarray(x))
            common.emit(
                "e8my_sweep", f"{name}_D{D}",
                mantissa=22 - D,
                t_us=t * 1e6,
                speedup_vs_fp32sell=base["fp32"] / t,
                speedup_vs_fp16sell=base["fp16"] / t,
                backward_error=be,
                dummy_frac=mm.n_dummy / max(a.nnz, 1),
            )
