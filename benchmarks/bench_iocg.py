"""Fig. 11/12 + Table 3 analogue: inner-outer CG with E8MY inner SpMV.

Four IO-CG variants (fp64 / fp32 / fp16 / best-E8MY) against the standard
FP64 PCG baseline, for m_in ∈ {20, 50}; the E8MY grid reproduces the
Table 3 "best format" selection.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.core import testmats
from repro.solvers import iocg
from repro.solvers.operators import OperatorSet, sym_scale

from . import common

E8M_GRID = (2, 6, 10, 12)      # delta widths D -> mantissa 22-D
M_IN_GRID = (20, 50)


def _problems(scale: str) -> dict:
    if scale == "tiny":
        return {"hpcg_6": testmats.hpcg(6, 6, 6)}
    if scale == "small":
        return {"hpcg_12": testmats.hpcg(12, 12, 12),
                "stencil1d_40k": testmats.stencil_1d(40_000, 3)}
    return {"hpcg_24": testmats.hpcg(24, 24, 24),
            "stencil1d_150k": testmats.stencil_1d(150_000, 3)}


def _true_relres(a, x, b) -> float:
    return float(np.linalg.norm(
        np.asarray(b, np.float64)
        - a.astype(np.float64) @ np.asarray(x, np.float64))
        / np.linalg.norm(np.asarray(b, np.float64)))


def run(scale: str | None = None) -> None:
    scale = scale or common.SCALE
    for name, a0 in _problems(scale).items():
        a, _ = sym_scale(a0)
        ops = OperatorSet(a, C=32, sigma=256)
        rng = np.random.default_rng(5)
        b = jnp.asarray(rng.random(a.shape[0]))

        # baseline: standard FP64 PCG
        t_pcg = common.time_fn(lambda: iocg.pcg_reference(ops, b),
                               warmup=1, repeats=3)
        x, info = iocg.pcg_reference(ops, b)
        common.emit("iocg_pcg_ref", name, t_s=t_pcg,
                    iters=int(info.iters),
                    true_relres=_true_relres(a, x, b))

        for m_in in M_IN_GRID:
            for variant in ("fp64", "fp32", "fp16"):
                cfg = iocg.variant(variant, m_in=m_in)
                t = common.time_fn(lambda: iocg.solve(ops, b, cfg),
                                   warmup=1, repeats=3)
                x, info = iocg.solve(ops, b, cfg)
                common.emit(
                    "iocg", f"{name}_min{m_in}_{variant}",
                    t_s=t, outer_iters=int(info.iters),
                    true_relres=_true_relres(a, x, b),
                    speedup_vs_pcg=t_pcg / t)

            # Table 3: best E8MY format over the D grid
            best = None
            for D in E8M_GRID:
                cfg = iocg.variant(f"e8m{D}", m_in=m_in)
                t = common.time_fn(lambda: iocg.solve(ops, b, cfg),
                                   warmup=1, repeats=3)
                x, info = iocg.solve(ops, b, cfg)
                rr = _true_relres(a, x, b)
                common.emit(
                    "iocg_e8m_grid", f"{name}_min{m_in}_D{D}",
                    mantissa=22 - D, t_s=t, outer_iters=int(info.iters),
                    true_relres=rr, speedup_vs_pcg=t_pcg / t)
                if rr < 1e-8 and (best is None or t < best[1]):
                    best = (D, t)
            if best is not None:
                common.emit(
                    "iocg_best_format", f"{name}_min{m_in}",
                    best_format=f"E8M{22 - best[0]}",
                    speedup_vs_pcg=t_pcg / best[1])
